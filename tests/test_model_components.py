"""Component-level model tests: SSD chunked scan vs naive recurrence, MoE
ragged vs dense oracle, sliding-window attention, MLA absorption, RoPE."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rope_angles


def _ssm_cfg(chunk=8, state=8, head_dim=8, d_model=32):
    return ModelConfig(name="t", family="ssm", n_layers=1, d_model=d_model,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=7,
                       ssm_state=state, ssm_head_dim=head_dim,
                       ssm_chunk=chunk)


def _naive_ssd(cfg, p, x):
    """Token-by-token recurrence oracle (what ssm_decode does, looped)."""
    B, L, _ = x.shape
    cache = {"conv": jnp.zeros((B, cfg.ssm_conv_width - 1,
                                cfg.d_inner + 2 * cfg.ssm_groups
                                * cfg.ssm_state), x.dtype),
             "state": jnp.zeros((B, cfg.ssm_heads, cfg.ssm_state,
                                 cfg.ssm_head_dim), jnp.float32)}
    ys = []
    for t in range(L):
        y, cache = ssm_mod.ssm_decode(cfg, p, x[:, t:t + 1], cache)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), cache


@pytest.mark.parametrize("L", [8, 16, 24])
def test_ssd_chunked_matches_recurrence(L):
    cfg = _ssm_cfg(chunk=8)
    p = ssm_mod.ssm_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, L, cfg.d_model)) * 0.5
    y_chunk = ssm_mod.ssm_forward(cfg, p, x)
    y_naive, _ = _naive_ssd(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=2e-4, atol=2e-4)


def test_ssd_prefill_state_matches_recurrence():
    cfg = _ssm_cfg(chunk=8)
    p = ssm_mod.ssm_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(2), (1, 19, cfg.d_model)) * 0.5
    _, state, conv_tail = ssm_mod.ssm_prefill(cfg, p, x)
    _, cache = _naive_ssd(cfg, p, x)
    np.testing.assert_allclose(np.asarray(state), np.asarray(cache["state"]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(conv_tail),
                               np.asarray(cache["conv"]), rtol=1e-5, atol=1e-5)


def test_ssd_padding_invariance():
    """Same input, different chunk sizes => same output."""
    p = None
    outs = []
    for chunk in (4, 8, 16):
        cfg = _ssm_cfg(chunk=chunk)
        if p is None:
            p = ssm_mod.ssm_init(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(3), (1, 12, cfg.d_model))
        outs.append(np.asarray(ssm_mod.ssm_forward(cfg, p, x)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_cfg(impl, E=4, k=2, shared=1):
    return ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=7,
                       n_experts=E, experts_per_token=k,
                       n_shared_experts=shared, moe_d_ff=48, moe_impl=impl)


@given(st.integers(0, 5), st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_moe_ragged_matches_dense(seed, k):
    cfg_d = _moe_cfg("dense", k=k)
    cfg_r = _moe_cfg("ragged", k=k)
    p = moe_mod.moe_init(jax.random.key(seed), cfg_d, jnp.float32)
    x = jax.random.normal(jax.random.key(seed + 100), (2, 6, 32))
    yd, auxd = moe_mod.moe_apply(cfg_d, p, x)
    yr, auxr = moe_mod.moe_apply(cfg_r, p, x)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(auxd), float(auxr), rtol=1e-5)


def test_moe_ragged_grads_match_dense():
    cfg_d, cfg_r = _moe_cfg("dense"), _moe_cfg("ragged")
    p = moe_mod.moe_init(jax.random.key(0), cfg_d, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, 32))

    def loss(params, cfg):
        y, aux = moe_mod.moe_apply(cfg, params, x)
        return jnp.sum(y ** 2) + aux

    gd = jax.grad(lambda q: loss(q, cfg_d))(p)
    gr = jax.grad(lambda q: loss(q, cfg_r))(p)
    for a, b in zip(jax.tree.leaves(gd), jax.tree.leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)


def test_moe_aux_loss_balanced_router_is_one():
    """Perfectly uniform routing gives aux ~= 1 (Switch normalization)."""
    cfg = _moe_cfg("dense", E=4, k=1, shared=0)
    p = moe_mod.moe_init(jax.random.key(0), cfg, jnp.float32)
    # router weights zero => uniform probs; top-1 picks expert 0 always,
    # so f = (E,0,0,0)... instead use symmetric tokens to check formula range
    p["router"] = jnp.zeros_like(p["router"])
    x = jax.random.normal(jax.random.key(1), (1, 16, 32))
    _, aux = moe_mod.moe_apply(cfg, p, x)
    # P_e = 1/E; f_e = E * frac; aux = sum_e f_e / E = 1
    assert float(aux) == pytest.approx(1.0, rel=1e-5)


# ---------------------------------------------------------------------------
# Attention details
# ---------------------------------------------------------------------------

def _attn_cfg(**kw):
    base = dict(name="t", family="dense", n_layers=1, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=7, head_dim=16)
    base.update(kw)
    return ModelConfig(**base)


def test_sliding_window_masks_old_positions():
    """With window w, logits at position i must not depend on tokens
    earlier than i - w + 1."""
    cfg = _attn_cfg(sliding_window=4)
    p = attn_mod.attn_init(jax.random.key(0), cfg, jnp.float32)
    S = 12
    x1 = jax.random.normal(jax.random.key(1), (1, S, 64))
    x2 = x1.at[:, 0:3].set(jax.random.normal(jax.random.key(2), (1, 3, 64)))
    pos = jnp.arange(S)
    y1 = attn_mod.attention_full(cfg, p, x1, pos)
    y2 = attn_mod.attention_full(cfg, p, x2, pos)
    # positions >= 3 + window - 1 = 6 see identical windows
    np.testing.assert_allclose(np.asarray(y1[:, 7:]), np.asarray(y2[:, 7:]),
                               rtol=1e-5, atol=1e-5)
    # position 3 attends to 0..3, so it must differ
    assert float(jnp.abs(y1[:, 3] - y2[:, 3]).max()) > 1e-6


def test_ring_cache_decode_matches_window_forward():
    """Decode through a ring cache of size == window reproduces the
    sliding-window full forward, far beyond the buffer length."""
    cfg = _attn_cfg(sliding_window=4)
    p = attn_mod.attn_init(jax.random.key(0), cfg, jnp.float32)
    S = 20
    x = jax.random.normal(jax.random.key(1), (1, S, 64))
    pos = jnp.arange(S)
    y_full = attn_mod.attention_full(cfg, p, x, pos)

    cache = jax.tree.map(lambda a: a[0],
                         attn_mod.make_kv_cache(cfg, 1, 4, 1, jnp.float32))
    ys = []
    for t in range(S):
        y, cache = attn_mod.attention_decode(
            cfg, p, x[:, t:t + 1], cache, jnp.asarray(t, jnp.int32))
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)


def test_gqa_reduces_to_mha_when_kv_equal():
    cfg_mha = _attn_cfg(n_kv_heads=4)
    p = attn_mod.attn_init(jax.random.key(0), cfg_mha, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, 64))
    y = attn_mod.attention_full(cfg_mha, p, x, jnp.arange(8))
    assert y.shape == (2, 8, 64)
    assert bool(jnp.isfinite(y).all())


def test_rope_preserves_norm_and_relative_property():
    pos = jnp.arange(16)
    cos, sin = rope_angles(pos, 32)
    x = jax.random.normal(jax.random.key(0), (1, 16, 2, 32))
    xr = apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(xr, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)
    # relative property: <q_i, k_j> depends only on i - j
    q = jnp.ones((1, 16, 1, 32))
    k = jnp.ones((1, 16, 1, 32))
    qr = apply_rope(q, cos, sin)[0, :, 0]
    kr = apply_rope(k, cos, sin)[0, :, 0]
    d1 = float(qr[5] @ kr[3])
    d2 = float(qr[9] @ kr[7])
    assert d1 == pytest.approx(d2, rel=1e-5)


def test_partial_rope_leaves_tail_unrotated():
    pos = jnp.arange(4) + 7
    x = jax.random.normal(jax.random.key(0), (1, 4, 1, 32))
    rot = int(32 * 0.25)
    cos, sin = rope_angles(pos, rot)
    xr = apply_rope(x, cos, sin, fraction=0.25)
    np.testing.assert_array_equal(np.asarray(xr[..., rot:]),
                                  np.asarray(x[..., rot:]))
    assert float(jnp.abs(xr[..., :rot] - x[..., :rot]).max()) > 1e-6
