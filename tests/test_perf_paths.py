"""Correctness of the §Perf optimization paths against their references:
chunked attention (incl. non-multiple sequence lengths), chunked MLA,
seq-chunked loss, ring-buffer roll fast path, prefill cache sizing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.kernels.flash_attention.ref import attention_ref
from repro.models import attention as attn
from repro.models.config import ModelConfig
from repro.models.model import Model


def _mini_cfg(**kw) -> ModelConfig:
    base = dict(name="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=97, remat=False)
    base.update(kw)
    return ModelConfig(**base)


# ---------------------------------------------------------------------------
# chunked attention == reference (the 32k-prefill memory path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,chunk,window", [
    (64, 16, None),       # exact multiple
    (72, 16, None),       # padded queries (the VLM/audio prefix case)
    (64, 16, 24),         # sliding window
    (40, 64, None),       # chunk > S
    (96, 32, 16),
])
def test_chunked_sdpa_matches_ref(S, chunk, window):
    rng = np.random.default_rng(0)
    B, Hq, Hkv, hd = 2, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)), jnp.float32)
    cfg = _mini_cfg(attn_impl="chunked", attn_chunk=chunk,
                    sliding_window=window)
    got = attn._chunked_sdpa(q, k, v, window, cfg)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.reshape(B, S, Hq * hd)),
                               rtol=2e-5, atol=2e-5)


def test_chunked_attention_full_model_path():
    """attn_impl='chunked' must match 'ref' through the whole model."""
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, 97, (2, 72)), jnp.int32)
    cfg_ref = _mini_cfg(attn_impl="ref")
    cfg_chk = _mini_cfg(attn_impl="chunked", attn_chunk=16)
    m = Model(cfg_ref)
    params = m.init(jax.random.key(0))
    lr, _ = m.forward(params, toks)
    lc, _ = Model(cfg_chk).forward(params, toks)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(lr),
                               rtol=2e-4, atol=2e-4)


def test_chunked_mla_matches_full():
    cfg = get_config("deepseek-v2-236b", reduced=True)
    cfg_chk = dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=16)
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 72)), jnp.int32)
    lr, _ = m.forward(params, toks)
    lc, _ = Model(cfg_chk).forward(params, toks)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(lr),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# seq-chunked loss (no full fp32 logits) == plain loss
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.sampled_from([16, 32, 64]), st.integers(0, 2 ** 31 - 1))
def test_loss_chunk_equivalence(chunk, seed):
    cfg = _mini_cfg()
    cfg_c = dataclasses.replace(cfg, loss_chunk=chunk)
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(seed)
    tok = jnp.asarray(rng.integers(0, 97, (2, 64)), jnp.int32)
    tgt = jnp.asarray(rng.integers(0, 97, (2, 64)), jnp.int32)
    l0 = float(m.loss(params, (tok, tgt)))
    l1 = float(Model(cfg_c).loss(params, (tok, tgt)))
    assert abs(l0 - l1) < 1e-5 * max(1.0, abs(l0))


def test_loss_chunk_gradients_match():
    cfg = _mini_cfg()
    cfg_c = dataclasses.replace(cfg, loss_chunk=16)
    m = Model(cfg)
    params = m.init(jax.random.key(3))
    rng = np.random.default_rng(3)
    tok = jnp.asarray(rng.integers(0, 97, (2, 64)), jnp.int32)
    tgt = jnp.asarray(rng.integers(0, 97, (2, 64)), jnp.int32)
    g0 = jax.grad(m.loss)(params, (tok, tgt))
    g1 = jax.grad(Model(cfg_c).loss)(params, (tok, tgt))
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# ring-buffer construction: roll/identity fast paths == scatter semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,W", [(8, 8), (12, 8), (16, 8), (6, 8), (20, 8)])
def test_scatter_ring_layouts(S, W):
    cfg = _mini_cfg()
    m = Model(cfg)
    full = jnp.arange(2 * 1 * S * 3, dtype=jnp.float32).reshape(2, 1, S, 3)
    buf, kpos = m._scatter_ring(full, W, axis_seq=2)
    assert buf.shape[2] == W
    # every stored position must sit in slot pos % W with the right value
    kp = np.asarray(kpos)
    bf = np.asarray(buf)
    fl = np.asarray(full)
    for slot in range(W):
        pos = kp[slot]
        if pos < 0:
            continue
        assert pos % W == slot
        np.testing.assert_array_equal(bf[:, :, slot], fl[:, :, pos])
    # exactly the last min(S, W) positions are retained
    kept = sorted(p for p in kp if p >= 0)
    assert kept == list(range(max(S - W, 0), S))


def test_prefill_cache_covers_frontend_prefix():
    """Prefill + decode must stay exact for frontend (VLM/audio) archs:
    the cache covers prefix positions (regression: prefix was truncated)."""
    cfg = get_config("internvl2-1b", reduced=True)
    m = Model(cfg)
    params = m.init(jax.random.key(4))
    rng = np.random.default_rng(4)
    K = 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, K)), jnp.int32)
    pe = jnp.asarray(rng.standard_normal(
        (1, cfg.frontend_len, cfg.frontend_dim)), jnp.float32)
    P = cfg.frontend_len
    full, _ = m.forward(params, toks, pe)
    # cache sized exactly P + K (the dry-run's prefill sizing)
    logits_pre, cache = m.prefill(params, toks[:, :K - 1], pe,
                                  max_len=P + K)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(full[:, P + K - 2]),
                               rtol=2e-3, atol=2e-3)
    pos = jnp.asarray(P + K - 1, jnp.int32)
    logits_dec, _ = m.decode(params, cache, toks[:, K - 1], pos)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(full[:, P + K - 1]),
                               rtol=2e-3, atol=2e-3)


def test_cache_len_for_adds_prefix_on_prefill():
    from repro.launch.shapes import SHAPES, cache_len_for, production_config
    cfg = production_config(get_config("internvl2-1b"),
                            SHAPES["prefill_32k"])
    assert cache_len_for(cfg, SHAPES["prefill_32k"]) == 32768 + 256
    assert cache_len_for(cfg, SHAPES["decode_32k"]) == 32768
    cfg_l = production_config(get_config("internvl2-1b"),
                              SHAPES["long_500k"])
    assert cache_len_for(cfg_l, SHAPES["long_500k"]) == 8192
