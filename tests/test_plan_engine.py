"""Declarative RoundPlan + Engine API (ISSUE 4 tentpole).

Covers: legacy-kwarg shims (DeprecationWarning + History equivalence),
the backend-selection matrix in ``resolve_backend``, straggler masks
(``active_t``) -- all-ones bitwise-identical to the unmasked paths,
dropped clients matching a dense oracle that zeros their deltas and
renormalizes -- plan constructors, JSON round-trips, and the
plan-driven ``FederatedServer.run``.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (D2DNetwork, FederatedServer, ServerConfig,
                        client_deltas, global_update, make_round_fn,
                        make_scanned_rounds, mix_deltas)
from repro.core.rounds import mask_clients
from repro.fl import ExecutionConfig, RoundPlan, make_engine, plan_rows, \
    resolve_backend
from repro.kernels.mixing.ops import combine_weights

jax.config.update("jax_enable_x64", False)


def quad_loss(params, batch):
    x = params["x"]
    b, = batch
    return 0.5 * jnp.sum((x - b.mean(axis=0)) ** 2)


def _net_cfg(n=12, c=2, t_max=5, seed=3, **kw):
    net = D2DNetwork(n=n, c=c, k_range=(4, 6), p_fail=0.1)
    cfg = ServerConfig(T=3, t_max=t_max, phi_max=0.3, seed=seed,
                       eta=lambda t: 0.2 / (1 + 0.3 * t), **kw)
    return net, cfg


def _sampler(n, p, T=3, B=2):
    targets = np.random.default_rng(11).standard_normal((n, p)) \
        .astype(np.float32)

    def sampler(r, t):
        samp = targets[:, None, None, :] \
            + 0.05 * r.standard_normal((n, T, B, p))
        return (jnp.asarray(samp, jnp.float32),)

    return sampler


def _server(execution=None, p=4, eval_key="gap", **kw):
    net, cfg = _net_cfg()
    server = FederatedServer(net, quad_loss, {"x": jnp.zeros(p)},
                             _sampler(net.n, p), cfg, algorithm="semidec",
                             execution=execution, **kw)
    hist = server.run(eval_fn=lambda prm: {
        eval_key: float(jnp.sum(prm["x"] ** 2))})
    return server, hist


def _round_setup(seed=9, n=6, p=5, T=3, B=2):
    rng = np.random.default_rng(seed)
    batches = (jnp.asarray(rng.standard_normal((n, T, B, p)), jnp.float32),)
    A = jnp.asarray(rng.random((n, n)), jnp.float32)
    tau = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    m = jnp.float32(max(1.0, float(tau.sum())))
    return batches, A, tau, m, jnp.float32(0.1), {"x": jnp.zeros(p)}


# ---------------------------------------------------------------------------
# legacy kwargs: DeprecationWarning + History equivalence
# ---------------------------------------------------------------------------

def test_legacy_kwargs_warn_and_match_execution_config():
    with pytest.warns(DeprecationWarning, match="mixing_backend"):
        s_old, h_old = _server(mixing_backend="fused", scan_rounds=True)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        s_new, h_new = _server(
            execution=ExecutionConfig(backend="fused", scan=True))
    assert s_old.effective_backend == s_new.effective_backend == "aggregate"
    np.testing.assert_array_equal(np.asarray(s_old.params["x"]),
                                  np.asarray(s_new.params["x"]))
    assert len(h_old.records) == len(h_new.records)
    for a, b in zip(h_old.records, h_new.records):
        assert (a.t, a.m, a.m_actual, a.d2s, a.d2d, a.eta, a.psi_bound,
                a.metrics) == \
            (b.t, b.m, b.m_actual, b.d2s, b.d2d, b.eta, b.psi_bound,
             b.metrics)
    np.testing.assert_array_equal(h_old.ledger.cumulative_cost(),
                                  h_new.ledger.cumulative_cost())


def test_execution_config_and_legacy_kwargs_conflict():
    net, cfg = _net_cfg()
    with pytest.raises(ValueError, match="not both"):
        FederatedServer(net, quad_loss, {"x": jnp.zeros(4)},
                        _sampler(net.n, 4), cfg,
                        execution=ExecutionConfig(),
                        mixing_backend="fused")
    # the jit kwarg must not be silently dropped when it contradicts
    # the ExecutionConfig
    with pytest.raises(ValueError, match="jit"):
        FederatedServer(net, quad_loss, {"x": jnp.zeros(4)},
                        _sampler(net.n, 4), cfg, jit=False,
                        execution=ExecutionConfig())
    # agreeing values are fine
    FederatedServer(net, quad_loss, {"x": jnp.zeros(4)},
                    _sampler(net.n, 4), cfg, jit=True,
                    execution=ExecutionConfig())


@pytest.mark.parametrize("ecfg,effective", [
    (ExecutionConfig(backend="fused"), "aggregate"),
    (ExecutionConfig(backend="pallas"), "aggregate"),
    (ExecutionConfig(backend="fused", record_mixed=True), "fused"),
    (ExecutionConfig(backend="einsum"), "einsum"),
    (ExecutionConfig(backend="aggregate"), "aggregate"),
])
def test_resolve_backend_matrix(ecfg, effective):
    assert resolve_backend(ecfg) == effective


def test_resolve_backend_rejects_invalid_combinations():
    with pytest.raises(ValueError, match="mixing_backend"):
        resolve_backend(ExecutionConfig(backend="nope"))
    with pytest.raises(ValueError, match="record_mixed"):
        resolve_backend(ExecutionConfig(backend="aggregate",
                                        record_mixed=True))
    with pytest.raises(ValueError, match="model_cfg"):
        resolve_backend(ExecutionConfig(backend="fused", mesh=object()))
    with pytest.raises(ValueError, match="mesh mixing"):
        resolve_backend(ExecutionConfig(backend="pallas", mesh=object(),
                                        model_cfg=object()))


# ---------------------------------------------------------------------------
# straggler masks: all-ones == unmasked, bitwise, on every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend",
                         ["einsum", "pallas", "fused", "aggregate"])
def test_round_fn_all_ones_active_is_bitwise_noop(backend):
    batches, A, tau, m, eta, params = _round_setup()
    fn = make_round_fn(quad_loss, mixing_backend=backend, chunk=256)
    p0, mx0 = fn(params, batches, A, tau, m, eta)
    p1, mx1 = fn(params, batches, A, tau, m, eta,
                 jnp.ones_like(tau))
    np.testing.assert_array_equal(np.asarray(p0["x"]), np.asarray(p1["x"]))
    if mx0 is not None:
        np.testing.assert_array_equal(np.asarray(mx0["x"]),
                                      np.asarray(mx1["x"]))


def test_combine_weights_all_ones_active_is_bitwise_noop():
    _, A, tau, m, _, _ = _round_setup()
    w0 = combine_weights(A, tau, m)
    w1 = combine_weights(A, tau, m, jnp.ones_like(tau))
    np.testing.assert_array_equal(np.asarray(w0), np.asarray(w1))


# ---------------------------------------------------------------------------
# dropout: every backend matches the dense oracle (zero the dropped
# client's delta, remove its upload, renormalize by the effective count)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend",
                         ["einsum", "pallas", "fused", "aggregate"])
def test_dropout_round_matches_dense_oracle(backend):
    batches, A, tau, _, eta, params = _round_setup()
    # drop a sampled client and an unsampled D2D neighbor
    active = jnp.asarray([1, 1, 0, 1, 0, 1], jnp.float32)
    m_eff = jnp.float32(max(1.0, float((tau * active).sum())))

    deltas = client_deltas(quad_loss, params, batches, eta)
    mixed = mix_deltas(A, mask_clients(deltas, active))
    want = global_update(params, mixed, tau * active, m_eff)

    fn = make_round_fn(quad_loss, mixing_backend=backend, chunk=256)
    got, got_mixed = fn(params, batches, A, tau, m_eff, eta, active)
    np.testing.assert_allclose(np.asarray(got["x"]), np.asarray(want["x"]),
                               rtol=1e-5, atol=1e-6)
    if got_mixed is not None:
        np.testing.assert_allclose(np.asarray(got_mixed["x"]),
                                   np.asarray(mixed["x"]),
                                   rtol=1e-5, atol=1e-6)


def test_scanned_rounds_with_dropout_bitwise_vs_sequential():
    rng = np.random.default_rng(21)
    n, p, T, B, K = 5, 4, 3, 2, 4
    batches, As, taus, ms, actives = [], [], [], [], []
    targets = rng.standard_normal((n, p))
    for _ in range(K):
        samp = targets[:, None, None, :] \
            + 0.05 * rng.standard_normal((n, T, B, p))
        batches.append((jnp.asarray(samp, jnp.float32),))
        As.append(jnp.asarray(rng.random((n, n)), jnp.float32))
        tau = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
        act = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
        taus.append(tau)
        actives.append(act)
        ms.append(jnp.float32(max(1.0, float((tau * act).sum()))))
    etas = [jnp.float32(0.2 / (1 + t)) for t in range(K)]
    params = {"x": jnp.zeros(p)}

    round_fn = make_round_fn(quad_loss)
    seq, prm = [], params
    for t in range(K):
        prm, _ = round_fn(prm, batches[t], As[t], taus[t], ms[t], etas[t],
                          actives[t])
        seq.append(np.asarray(prm["x"]))

    scanned = make_scanned_rounds(quad_loss, K)
    batches_seq = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    final, params_seq = scanned(params, batches_seq, jnp.stack(As),
                                jnp.stack(taus), jnp.stack(ms),
                                jnp.stack(etas), jnp.stack(actives))
    np.testing.assert_array_equal(np.asarray(final["x"]), seq[-1])
    for t in range(K):
        np.testing.assert_array_equal(np.asarray(params_seq["x"][t]), seq[t])


@pytest.mark.parametrize("backend", ["einsum", "fused", "aggregate"])
@pytest.mark.parametrize("scan", [False, True])
def test_server_dropout_plan_consistent_across_backends(backend, scan):
    """A dropout plan executes to the same trajectory on every backend
    (einsum is the oracle), sequential and scanned."""
    net, cfg = _net_cfg()
    plan = RoundPlan.connectivity_aware(net, cfg).with_dropout(
        0.4, np.random.default_rng(5))
    assert plan.has_dropout

    def run(ecfg):
        server = FederatedServer(net, quad_loss, {"x": jnp.zeros(4)},
                                 _sampler(net.n, 4), cfg,
                                 execution=ecfg)
        hist = server.run(plan=plan)
        return server, hist

    s_ref, h_ref = run(ExecutionConfig(backend="einsum"))
    s_got, h_got = run(ExecutionConfig(backend=backend, scan=scan))
    np.testing.assert_allclose(np.asarray(s_got.params["x"]),
                               np.asarray(s_ref.params["x"]),
                               rtol=1e-5, atol=1e-6)
    # effective uploads drive the ledger: fewer than the dense plan's
    dense_d2s = (plan.tau_t.sum(axis=1)).astype(int)
    for t, rec in enumerate(h_got.records):
        assert rec.d2s == int(plan.d2s_t[t]) <= dense_d2s[t]
    np.testing.assert_array_equal(h_got.ledger.cumulative_cost(),
                                  h_ref.ledger.cumulative_cost())


# ---------------------------------------------------------------------------
# zero-survivor round: when every client drops, the round is a no-op --
# the eq.-4 divisor clamps to 1, the aggregate is exactly zero, params
# carry forward bitwise, and the History records m_actual=0.  Pinned on
# every mixing backend, sequential and scanned.  (The mesh analogue is
# test_mesh_train_step_dropped_client_is_identity; the semi-async
# analogue is the deadline-shortfall test in test_stream_engine.py.)
# ---------------------------------------------------------------------------

def _zero_survivor_plan():
    net, cfg = _net_cfg(t_max=3)
    plan = RoundPlan.connectivity_aware(net, cfg)
    active = np.ones_like(plan.active_t)
    active[1, :] = 0.0                      # everybody drops in round 1
    plan = plan.with_active(active)
    assert int(plan.m_actual_t[1]) == 0 and float(plan.m_t[1]) == 1.0
    return net, cfg, plan


@pytest.mark.parametrize("backend",
                         ["einsum", "pallas", "fused", "aggregate"])
@pytest.mark.parametrize("scan", [False, True])
def test_zero_survivor_round_is_noop_every_backend(backend, scan):
    net, cfg, plan = _zero_survivor_plan()

    def run(p):
        server = FederatedServer(net, quad_loss, {"x": jnp.zeros(4)},
                                 _sampler(net.n, 4), cfg,
                                 execution=ExecutionConfig(backend=backend,
                                                           scan=scan))
        hist = server.run(plan=p)
        return server.params, hist

    params_full, hist = run(plan)
    # the dead round is recorded, finite, and free
    rec = hist.records[1]
    assert rec.m_actual == 0 and rec.d2s == 0
    assert np.isfinite(np.asarray(params_full["x"])).all()
    # params across the dead round are bitwise those of the truncated
    # run: rounds 0..2 with round 1 dead == rounds {0, 2} never happen,
    # so compare against stopping right before the dead round
    params_head, _ = run(plan[:1])
    params_resumed, _ = run(plan[:2])
    np.testing.assert_array_equal(np.asarray(params_resumed["x"]),
                                  np.asarray(params_head["x"]))


def test_zero_survivor_round_backends_agree_bitwise():
    """All backends produce the identical trajectory through a dead
    round (the clamp-to-1 divisor is shared, not per-backend)."""
    net, cfg, plan = _zero_survivor_plan()

    def run(backend):
        server = FederatedServer(net, quad_loss, {"x": jnp.zeros(4)},
                                 _sampler(net.n, 4), cfg,
                                 execution=ExecutionConfig(backend=backend))
        server.run(plan=plan)
        return np.asarray(server.params["x"])

    ref = run("einsum")
    for backend in ("pallas", "fused", "aggregate"):
        np.testing.assert_allclose(run(backend), ref,
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# RoundPlan: constructors, transforms, serialization
# ---------------------------------------------------------------------------

def test_plan_constructors_shapes_and_semantics():
    net, cfg = _net_cfg(t_max=4)
    plan = RoundPlan.connectivity_aware(net, cfg)
    K, n = cfg.t_max, net.n
    assert (plan.n_rounds, plan.n_clients) == (K, n)
    assert plan.A_t.shape == (K, n, n) and plan.tau_t.shape == (K, n)
    assert not plan.has_dropout
    # equal-neighbor matrices are column-stochastic
    np.testing.assert_allclose(plan.A_t.sum(axis=1), 1.0, atol=1e-5)
    assert (plan.m_actual_t == plan.tau_t.sum(axis=1)).all()
    assert (plan.d2s_t == plan.m_actual_t).all()
    assert np.isfinite(plan.psi_bound_t).all()

    cfg_f = ServerConfig(T=3, t_max=4, m_fixed=6, seed=1)
    fed = RoundPlan.fedavg(net, cfg_f)
    assert (fed.A_t == np.eye(n, dtype=np.float32)).all()
    assert (fed.d2d_t == 0).all() and (fed.m_planned_t == 6).all()
    assert np.isnan(fed.psi_bound_t).all()

    col = RoundPlan.colrel(net, cfg_f)
    assert (col.d2d_t > 0).all() and (col.m_planned_t == 6).all()

    with pytest.raises(ValueError, match="m_fixed"):
        RoundPlan.fedavg(net, ServerConfig(t_max=2))


def test_plan_rows_generator_matches_constructor():
    """The row generator and the constructor consume identical rng
    streams -- interleaving foreign draws between rows must not change
    the rows themselves."""
    net, cfg = _net_cfg(t_max=3)
    whole = RoundPlan.connectivity_aware(
        net, cfg, rng=np.random.default_rng(cfg.seed))
    gen = plan_rows(net, cfg, "semidec", np.random.default_rng(cfg.seed))
    rows = [next(gen) for _ in range(cfg.t_max)]
    assert whole.allclose(RoundPlan.from_rows(rows, "semidec"))


def test_plan_with_active_renormalizes_bookkeeping():
    net, cfg = _net_cfg(t_max=3)
    plan = RoundPlan.connectivity_aware(net, cfg)
    active = np.ones_like(plan.active_t)
    active[1, :] = 0.0                       # everyone drops in round 1
    dropped = plan.with_active(active)
    eff = (plan.tau_t * active).sum(axis=1)
    assert (dropped.m_actual_t == eff).all()
    assert (dropped.d2s_t == eff).all()
    np.testing.assert_array_equal(dropped.m_t, np.maximum(eff, 1.0))
    assert dropped.m_t[1] == 1.0             # clamped, like a tau=0 round
    # planner metadata untouched; D2D billing loses the dropped senders'
    # outgoing edges (round 1: everyone silent => zero D2D transmissions)
    np.testing.assert_array_equal(dropped.m_planned_t, plan.m_planned_t)
    np.testing.assert_array_equal(dropped.d2d_t[[0, 2]],
                                  plan.d2d_t[[0, 2]])
    assert dropped.d2d_t[1] == 0 < plan.d2d_t[1]
    # an all-ones mask leaves every column bit-identical
    assert plan.with_active(np.ones_like(plan.active_t)).allclose(plan)

    with pytest.raises(ValueError, match="shape"):
        plan.with_active(np.ones((2, 2)))
    with pytest.raises(ValueError, match="0/1"):
        plan.with_active(np.full_like(plan.active_t, 0.5))
    with pytest.raises(ValueError, match="rate"):
        plan.with_dropout(1.5)


def test_plan_json_round_trip_is_exact():
    net, cfg = _net_cfg(t_max=3)
    for plan in (RoundPlan.connectivity_aware(net, cfg),
                 RoundPlan.fedavg(net, ServerConfig(t_max=2, m_fixed=4)),
                 RoundPlan.connectivity_aware(net, cfg).with_dropout(
                     0.3, np.random.default_rng(2))):
        back = RoundPlan.from_json(plan.to_json())
        assert plan.allclose(back)
        assert back.has_dropout == plan.has_dropout

    with pytest.raises(ValueError, match="version"):
        RoundPlan.from_json('{"version": 99}')


def test_plan_json_round_trip_executes_to_identical_history():
    """to_json -> from_json -> execute == executing the original plan:
    identical History records, metrics, and final params (bitwise)."""
    net, cfg = _net_cfg()
    plan = RoundPlan.connectivity_aware(net, cfg).with_dropout(
        0.3, np.random.default_rng(7))

    def run(p):
        server = FederatedServer(
            net, quad_loss, {"x": jnp.zeros(4)}, _sampler(net.n, 4), cfg,
            execution=ExecutionConfig(backend="fused", scan=True))
        hist = server.run(eval_fn=lambda prm: {
            "l2": float(jnp.sum(prm["x"] ** 2))}, plan=p)
        return server, hist

    s1, h1 = run(plan)
    s2, h2 = run(RoundPlan.from_json(plan.to_json()))
    np.testing.assert_array_equal(np.asarray(s1.params["x"]),
                                  np.asarray(s2.params["x"]))
    for a, b in zip(h1.records, h2.records):
        assert (a.t, a.m, a.m_actual, a.d2s, a.d2d, a.eta, a.metrics) == \
            (b.t, b.m, b.m_actual, b.d2s, b.d2d, b.eta, b.metrics)


def test_server_last_plan_reruns_identically():
    """server.run() exposes the executed plan; re-running it through a
    fresh same-seeded server reproduces the History bitwise (the
    'reproducible trajectories' contract)."""
    s1, h1 = _server(execution=ExecutionConfig(backend="einsum"))
    assert s1.last_plan is not None and not s1.last_plan.has_dropout
    net, cfg = _net_cfg()
    s2 = FederatedServer(net, quad_loss, {"x": jnp.zeros(4)},
                         _sampler(net.n, 4), cfg,
                         execution=ExecutionConfig(backend="einsum"))
    h2 = s2.run(eval_fn=lambda prm: {"gap": float(jnp.sum(prm["x"] ** 2))})
    np.testing.assert_array_equal(np.asarray(s1.params["x"]),
                                  np.asarray(s2.params["x"]))
    assert s1.last_plan.allclose(s2.last_plan)
    for a, b in zip(h1.records, h2.records):
        assert a.metrics == b.metrics


def test_engine_rejects_mismatched_batches_and_plan():
    net, cfg = _net_cfg(t_max=3)
    plan = RoundPlan.connectivity_aware(net, cfg)
    engine = make_engine(ExecutionConfig(), quad_loss)
    with pytest.raises(ValueError, match="batch"):
        engine.execute(plan, {"x": jnp.zeros(4)}, [None])
    server = FederatedServer(net, quad_loss, {"x": jnp.zeros(4)},
                             _sampler(net.n, 4),
                             ServerConfig(t_max=3, seed=0))
    small = D2DNetwork(n=6, c=2, k_range=(2, 3))
    other = RoundPlan.connectivity_aware(small,
                                         ServerConfig(t_max=3, seed=0))
    with pytest.raises(ValueError, match="clients"):
        server.run(plan=other)


# ---------------------------------------------------------------------------
# mesh runtime (1-device debug mesh; the 8-device matrix is `-m mesh`)
# ---------------------------------------------------------------------------

def _tiny_mesh_setup():
    from repro.configs import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.models.model import Model

    mesh = make_debug_mesh((1, 1), axes=("data", "model"))
    cfg = get_config("stablelm-1.6b", reduced=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "vocab_size": 64,
                           "name": "tiny-plan"})
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 64, size=(1, 2, 2, 9)), jnp.int32)
    return mesh, cfg, params, toks


@pytest.mark.parametrize("mixing", ["fused", "fused_rs"])
def test_mesh_train_step_all_ones_active_is_bitwise_noop(mixing):
    from repro.fl import make_train_step

    mesh, cfg, params, toks = _tiny_mesh_setup()
    step = make_train_step(cfg, mesh, mixing=mixing)
    args = (params, toks, jnp.ones((1, 1), jnp.float32),
            jnp.ones((1,), jnp.float32), jnp.float32(1.0),
            jnp.float32(0.05))
    out0 = step(*args)
    out1 = step(*args, active=jnp.ones((1,), jnp.float32))
    for a, b in zip(jax.tree.leaves(out0), jax.tree.leaves(out1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("mixing", ["einsum", "fused", "fused_rs"])
def test_mesh_train_step_dropped_client_is_identity(mixing):
    """All clients dropped => zero aggregate => globals unchanged, on
    every mesh mixing schedule (the mesh analogue of the tau=0 round)."""
    from repro.fl import make_train_step

    mesh, cfg, params, toks = _tiny_mesh_setup()
    step = make_train_step(cfg, mesh, mixing=mixing)
    out = step(params, toks, jnp.ones((1, 1), jnp.float32),
               jnp.ones((1,), jnp.float32), jnp.float32(1.0),
               jnp.float32(0.05), active=jnp.zeros((1,), jnp.float32))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mesh_scanned_with_active_bitwise_vs_sequential():
    from repro.fl import make_scanned_train_steps, make_train_step

    mesh, cfg, params, _ = _tiny_mesh_setup()
    K = 2
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, 64, size=(K, 1, 2, 2, 9)),
                       jnp.int32)
    A_seq = jnp.ones((K, 1, 1), jnp.float32)
    tau_seq = jnp.ones((K, 1), jnp.float32)
    m_seq = jnp.ones((K,), jnp.float32)
    eta_seq = jnp.asarray([0.05, 0.02], jnp.float32)
    act_seq = jnp.asarray([[1.0], [0.0]], jnp.float32)

    step = make_train_step(cfg, mesh, mixing="fused")
    seq = params
    for t in range(K):
        seq = step(seq, toks[t], A_seq[t], tau_seq[t], m_seq[t],
                   eta_seq[t], active=act_seq[t])
    scanned = make_scanned_train_steps(cfg, mesh, K, mixing="fused")
    final, _ = scanned(params, toks, A_seq, tau_seq, m_seq, eta_seq,
                       active_seq=act_seq)
    for a, b in zip(jax.tree.leaves(seq), jax.tree.leaves(final)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
