"""Round-resumable plans + correlated straggler models (ISSUE 5
satellites).

Covers: ``RoundPlan.__getitem__`` (int -> PlanRow, slice -> sub-plan
with preserved columns/bookkeeping and a shifted ``t0``), crash/resume
through ``ckpt.checkpoint`` matching the uninterrupted History bitwise,
and the correlated dropout transforms (``with_markov_dropout`` bursty
chains, ``with_cluster_dropout`` whole-cluster outages) renormalizing
exactly like ``with_active``.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import topology
from repro.ckpt import load_checkpoint, save_checkpoint
from repro.core.graphs import D2DNetwork
from repro.core.server import ServerConfig
from repro.fl import ExecutionConfig, PlanRow, RoundPlan, make_engine


def quad_loss(params, batch):
    x = params["x"]
    b, = batch
    return 0.5 * jnp.sum((x - b.mean(axis=0)) ** 2)


def _plan(t_max=6, seed=3, n=12, c=2):
    net = D2DNetwork(n=n, c=c, k_range=(4, 6), p_fail=0.1)
    cfg = ServerConfig(T=2, t_max=t_max, phi_max=0.3, seed=seed,
                       eta=lambda t: 0.2 / (1 + 0.3 * t))
    return RoundPlan.connectivity_aware(net, cfg)


def _batches(n, rounds, p=4, T=2, B=2, seed=1):
    rng = np.random.default_rng(seed)
    targets = rng.standard_normal((n, p)).astype(np.float32)
    out = []
    for _ in range(rounds):
        samp = targets[:, None, None, :] \
            + 0.05 * rng.standard_normal((n, T, B, p))
        out.append((jnp.asarray(samp, jnp.float32),))
    return out


# ---------------------------------------------------------------------------
# __getitem__: rows and slices
# ---------------------------------------------------------------------------

def test_getitem_int_returns_plan_row():
    plan = _plan()
    row = plan[2]
    assert isinstance(row, PlanRow)
    assert row.t == 2 and row.m_planned == int(plan.m_planned_t[2])
    np.testing.assert_array_equal(row.A, plan.A_t[2])
    np.testing.assert_array_equal(row.tau, plan.tau_t[2])
    assert plan[-1].t == plan.n_rounds - 1
    assert len(plan) == plan.n_rounds
    with pytest.raises(IndexError):
        plan[plan.n_rounds]


def test_slice_preserves_columns_and_bookkeeping():
    plan = _plan(t_max=6)
    tail = plan[2:]
    assert tail.n_rounds == 4 and tail.t0 == 2
    assert tail.algorithm == plan.algorithm
    assert tail.topology == plan.topology     # provenance rides along
    for f in ("A_t", "tau_t", "m_t", "eta_t", "active_t", "m_planned_t",
              "m_actual_t", "d2s_t", "d2d_t"):
        np.testing.assert_array_equal(getattr(tail, f),
                                      getattr(plan, f)[2:])
    np.testing.assert_array_equal(tail.psi_bound_t, plan.psi_bound_t[2:])
    # nested slices compose the offset
    assert plan[2:][1:].t0 == 3
    # full slice is the identity (t0 = 0)
    assert plan[:].allclose(plan) and plan[:].t0 == 0
    with pytest.raises(ValueError, match="step"):
        plan[::2]
    with pytest.raises(ValueError, match="regenerate"):
        plan[1:].regenerate()


def test_slice_rows_carry_global_round_index():
    plan = _plan(t_max=5)
    assert plan[3:][0].t == 3                 # PlanRow.t is global


# ---------------------------------------------------------------------------
# crash/resume: ckpt.checkpoint + plan[t0:] == uninterrupted, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scan", [False, True])
def test_resume_from_checkpoint_matches_uninterrupted_bitwise(tmp_path,
                                                              scan):
    K, t0, n = 6, 3, 12
    plan = _plan(t_max=K).with_dropout(0.2, np.random.default_rng(7))
    batches = _batches(n, K)
    params0 = {"x": jnp.zeros(4)}

    def eval_fn(p):
        return {"l2": float(jnp.sum(p["x"] ** 2))}

    def engine():
        return make_engine(ExecutionConfig(backend="einsum", scan=scan),
                           quad_loss)

    # the uninterrupted run
    params_full, hist_full = engine().execute(plan, params0, batches,
                                              eval_fn=eval_fn)

    # the "crashed" run: execute the head, checkpoint, restore, resume
    params_head, hist_head = engine().execute(plan[:t0], params0,
                                              batches[:t0],
                                              eval_fn=eval_fn)
    path = save_checkpoint(str(tmp_path), t0, params_head,
                           meta={"t0": t0})
    restored, meta = load_checkpoint(path, like=params0)
    assert meta["meta"]["t0"] == t0
    params_res, hist_res = engine().execute(plan[t0:], restored,
                                            batches[t0:], eval_fn=eval_fn)

    np.testing.assert_array_equal(np.asarray(params_full["x"]),
                                  np.asarray(params_res["x"]))
    # stitched History == uninterrupted History (records carry global t)
    stitched = hist_head.records + hist_res.records
    assert [r.t for r in stitched] == [r.t for r in hist_full.records]
    for a, b in zip(stitched, hist_full.records):
        assert (a.m, a.m_actual, a.d2s, a.d2d, a.eta, a.psi_bound) == \
            (b.m, b.m_actual, b.d2s, b.d2d, b.eta, b.psi_bound)
        assert a.metrics == b.metrics
    # the ledgers stitch too
    np.testing.assert_array_equal(
        np.concatenate([hist_head.ledger.cumulative_cost(),
                        hist_head.ledger.cumulative_cost()[-1]
                        + hist_res.ledger.cumulative_cost()]),
        hist_full.ledger.cumulative_cost())


# ---------------------------------------------------------------------------
# correlated straggler models
# ---------------------------------------------------------------------------

def test_markov_dropout_renormalizes_like_with_active():
    plan = _plan()
    rng_mask = np.random.default_rng(5)
    dropped = plan.with_markov_dropout(0.3, 0.5, rng_mask)
    assert dropped.has_dropout
    # identical to routing the same mask through with_active
    want = plan.with_active(dropped.active_t)
    assert dropped.allclose(want)
    eff = (plan.tau_t * dropped.active_t).sum(axis=1)
    np.testing.assert_array_equal(dropped.m_actual_t, eff.astype(np.int64))
    np.testing.assert_array_equal(dropped.m_t, np.maximum(eff, 1.0))


def test_markov_dropout_zero_fail_is_noop_and_validates():
    plan = _plan()
    assert plan.with_markov_dropout(0.0, 0.5).allclose(plan)
    with pytest.raises(ValueError, match="p_fail"):
        plan.with_markov_dropout(1.5, 0.5)
    with pytest.raises(ValueError, match="p_recover"):
        plan.with_markov_dropout(0.5, -0.1)


def test_markov_dropout_is_bursty():
    """Same marginal dropout rate, very different temporal structure:
    the chain's outages must persist (mean run length ~ 1/p_recover)
    while iid outages last ~1 round."""
    plan = _plan(t_max=60)
    rate, p_rec = 0.3, 0.2
    p_fail = rate / (1 - rate) * p_rec        # stationary marginal = rate
    mk = plan.with_markov_dropout(p_fail, p_rec, np.random.default_rng(0))
    iid = plan.with_dropout(rate, np.random.default_rng(0))

    def mean_outage_run(active_t):
        runs = []
        for i in range(active_t.shape[1]):
            run = 0
            for v in active_t[:, i]:
                if v == 0:
                    run += 1
                elif run:
                    runs.append(run)
                    run = 0
            if run:
                runs.append(run)
        return np.mean(runs) if runs else 0.0

    # comparable marginal dropout...
    assert abs((1 - mk.active_t).mean() - (1 - iid.active_t).mean()) < 0.1
    # ...but much longer outages (expected ~1/p_rec = 5 vs ~1.4 for iid)
    assert mean_outage_run(mk.active_t) > 2 * mean_outage_run(iid.active_t)


def test_cluster_dropout_is_cluster_constant_and_renormalized():
    spec = topology.make_spec("erdos_renyi", n=12, c=3)
    plan = RoundPlan.connectivity_aware(
        spec.build(), ServerConfig(T=2, t_max=8, phi_max=0.3, seed=0))
    dropped = plan.with_cluster_dropout(0.4, np.random.default_rng(3))
    assert dropped.has_dropout
    partition = spec.build().partition
    for t in range(dropped.n_rounds):
        for verts in partition:
            vals = set(dropped.active_t[t, verts].tolist())
            assert len(vals) == 1        # whole cluster up or down
    assert dropped.allclose(plan.with_active(dropped.active_t))
    # explicit partition overrides the embedded spec
    explicit = plan.with_cluster_dropout(
        0.4, np.random.default_rng(3), partition=partition)
    assert explicit.allclose(dropped)
    with pytest.raises(ValueError, match="rate"):
        plan.with_cluster_dropout(1.0)


def test_cluster_dropout_without_partition_or_spec_raises():
    rows = [PlanRow(t=t, A=np.eye(4, dtype=np.float32),
                    tau=np.ones(4, np.float32), m=4.0, eta=0.1,
                    active=np.ones(4, np.float32), m_planned=4,
                    m_actual=4, d2s=4, d2d=0, psi_bound=float("nan"))
            for t in range(2)]
    bare = RoundPlan.from_rows(rows, "fedavg")
    with pytest.raises(ValueError, match="partition"):
        bare.with_cluster_dropout(0.3)


def test_correlated_dropout_executes_and_costs_less():
    """A Markov-dropout plan runs end-to-end and its ledger reflects the
    reduced uploads."""
    n, K = 12, 5
    plan = _plan(t_max=K, n=n).with_markov_dropout(
        0.4, 0.5, np.random.default_rng(1))
    engine = make_engine(ExecutionConfig(backend="aggregate"), quad_loss)
    params, hist = engine.execute(plan, {"x": jnp.zeros(4)},
                                  _batches(n, K))
    assert np.isfinite(np.asarray(params["x"])).all()
    assert [r.d2s for r in hist.records] == plan.d2s_t.tolist()
    dense = _plan(t_max=K, n=n)
    assert hist.ledger.total_d2s <= int(dense.tau_t.sum())
