"""Quantized payload groups end to end (ISSUE 8 tentpole).

Covers: ``QuantSpec`` validation + dict round-trip, per-storage
quantize/dequantize error bounds, nibble pack/unpack exactness, the
error-feedback fp32 identity, stochastic-rounding determinism and
unbiasedness, fused-dequant kernel parity (dense + sparse, with
straggler masks), round-fn backends against the einsum-quant oracle,
scan == sequential with the quantizer state as carry, plan JSON v5
round-trips (and v4 payloads loading quant-free), the backend-support
matrix in ``resolve_backend``, engine-level execution from both config
sources, the compressed-bytes gate ratios, and int8+EF convergence
tracking fp32 where EF-off int4 measurably diverges.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (D2DNetwork, FederatedServer, ServerConfig,
                        client_deltas, make_round_fn, make_scanned_rounds)
from repro.core.rounds import QUANT_BACKENDS
from repro.core.sparse import SparseA
from repro.fl import ExecutionConfig, RoundPlan, make_engine, \
    resolve_backend
from repro.fl import packing
from repro.fl.packing import QuantSpec
from repro.kernels.mixing.ops import (aggregate_grouped_q,
                                      mix_aggregate_grouped_q,
                                      sparse_aggregate_grouped_q,
                                      sparse_mix_aggregate_grouped_q)

jax.config.update("jax_enable_x64", False)

STORAGES = ("int8", "int4", "fp8")
# worst-case round-trip error per value, as a fraction of the block
# absmax: half a grid step for the integer grids, the e4m3 mantissa
# width (3 bits => rel err <= 2^-4, with headroom) for fp8
_ERR_FRAC = {"int8": 0.5 / 127, "int4": 0.5 / 7, "fp8": 0.08}


def _spec_for(storage, block=None):
    if block is None:
        block = 256 if storage == "int4" else 128
    return QuantSpec(storage=storage, block=block)


# ---------------------------------------------------------------------------
# QuantSpec validation + serialization
# ---------------------------------------------------------------------------

def test_quantspec_rejects_bad_config():
    with pytest.raises(ValueError, match="storage"):
        QuantSpec(storage="int2")
    with pytest.raises(ValueError, match="rounding"):
        QuantSpec(rounding="banker")
    with pytest.raises(ValueError, match="stochastic"):
        QuantSpec(storage="fp8", rounding="stochastic")
    with pytest.raises(ValueError, match="block"):
        QuantSpec(storage="int8", block=64)
    with pytest.raises(ValueError, match="block"):
        QuantSpec(storage="int4", block=384)  # not a multiple of 256


def test_quantspec_dict_roundtrip():
    spec = QuantSpec(storage="int4", block=512, rounding="stochastic",
                     error_feedback=False, seed=7)
    back = QuantSpec.from_dict(json.loads(json.dumps(spec.as_dict())))
    assert back == spec


# ---------------------------------------------------------------------------
# quantize/dequantize: error bounds, zero blocks, nibbles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("storage", STORAGES)
def test_roundtrip_error_bounded_per_block(storage):
    quant = _spec_for(storage)
    rng = np.random.default_rng(0)
    buf = jnp.asarray(rng.standard_normal((4, 2 * quant.block)) * 3.0,
                      jnp.float32)
    stored, scales = packing.quantize_group(buf, quant)
    dq = packing.dequantize_group(stored, scales, quant)
    err = np.abs(np.asarray(dq) - np.asarray(buf)).reshape(
        4, -1, quant.block)
    absmax = np.abs(np.asarray(buf)).reshape(4, -1, quant.block) \
        .max(axis=2, keepdims=True)
    assert (err <= _ERR_FRAC[storage] * absmax + 1e-7).all()


@pytest.mark.parametrize("storage", STORAGES)
def test_zero_block_dequantizes_to_exact_zeros(storage):
    quant = _spec_for(storage)
    buf = jnp.zeros((2, quant.block), jnp.float32)
    stored, scales = packing.quantize_group(buf, quant)
    assert (np.asarray(scales) == 0).all()
    assert (np.asarray(packing.dequantize_group(stored, scales, quant))
            == 0).all()


def test_nibble_pack_unpack_exact():
    rng = np.random.default_rng(1)
    v = jnp.asarray(rng.integers(-8, 8, size=(3, 256)), jnp.int8)
    packed = packing._pack_nibbles(v)
    assert packed.shape == (3, 128)
    np.testing.assert_array_equal(np.asarray(packing._unpack_nibbles(packed)),
                                  np.asarray(v))


def test_int4_grid_values_roundtrip_exact():
    """Values already on the int4 grid survive the round-trip bitwise."""
    quant = _spec_for("int4")
    rng = np.random.default_rng(2)
    scale = 0.25
    grid = rng.integers(-7, 8, size=(2, quant.block)) * scale
    buf = jnp.asarray(grid, jnp.float32)
    stored, scales = packing.quantize_group(buf, quant)
    dq = packing.dequantize_group(stored, scales, quant)
    np.testing.assert_allclose(np.asarray(dq), grid, rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# error feedback + stochastic rounding
# ---------------------------------------------------------------------------

def _tree(rng, n, bf16_cols=384, fp32_cols=130):
    return {"w": jnp.asarray(rng.standard_normal((n, bf16_cols)),
                             jnp.bfloat16),
            "b": jnp.asarray(rng.standard_normal((n, fp32_cols)),
                             jnp.float32)}


def test_error_feedback_residual_is_exact_roundtrip_error():
    rng = np.random.default_rng(3)
    n = 4
    tree = _tree(rng, n)
    quant = _spec_for("int8")
    spec = packing.pack_spec(tree, quant=quant)
    bufs = packing.pack(tree, spec)
    residuals, _ = packing.init_quant_state(spec, n)
    # seed non-zero residuals: one EF step first
    _, _, residuals = packing.quantize_packed(bufs, spec, residuals)
    stored, scales, new_res = packing.quantize_packed(bufs, spec, residuals)
    dq = packing.dequantize_packed(stored, scales, spec)
    for b, r, s, d in zip(bufs, residuals, new_res, dq):
        want = (np.asarray(b, np.float32) + np.asarray(r)) - np.asarray(d)
        np.testing.assert_array_equal(np.asarray(s), want)


def test_stochastic_rounding_deterministic_given_key():
    rng = np.random.default_rng(4)
    quant = QuantSpec(storage="int8", block=128, rounding="stochastic")
    buf = jnp.asarray(rng.standard_normal((3, 256)), jnp.float32)
    key = jax.random.PRNGKey(0)
    s1, sc1 = packing.quantize_group(buf, quant, key)
    s2, sc2 = packing.quantize_group(buf, quant, key)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(sc1), np.asarray(sc2))
    with pytest.raises(ValueError, match="PRNG key"):
        packing.quantize_group(buf, quant, None)


def test_stochastic_rounding_unbiased():
    quant = QuantSpec(storage="int8", block=128, rounding="stochastic")
    buf = jnp.full((1, 128), 0.35, jnp.float32)
    # fix the absmax so the grid is known: one value at 1.0
    buf = buf.at[0, 0].set(1.0)
    acc = np.zeros(128)
    trials = 400
    for i in range(trials):
        s, sc = packing.quantize_group(buf, quant, jax.random.PRNGKey(i))
        acc += np.asarray(packing.dequantize_group(s, sc, quant))[0]
    np.testing.assert_allclose(acc / trials, np.asarray(buf)[0],
                               atol=2e-3)


# ---------------------------------------------------------------------------
# kernel parity: fused dequant epilogue vs dequantized einsum oracle
# ---------------------------------------------------------------------------

def _quant_inputs(storage, n=8, seed=5):
    """Quantized wire payload + mask, with the rounds-layer straggler
    recipe already applied: dropped clients are zeroed out of the mixed
    leg by zeroing their rows of the *scales* (one multiply on the tiny
    side buffer, never the payload); the aggregate leg re-masks through
    the combine row, which is idempotent for 0/1 masks."""
    rng = np.random.default_rng(seed)
    tree = _tree(rng, n)
    quant = _spec_for(storage)
    spec = packing.pack_spec(tree, quant=quant)
    bufs = packing.pack(tree, spec)
    stored, scales, _ = packing.quantize_packed(bufs, spec)
    active = rng.integers(0, 2, n).astype(np.float32)
    scales = tuple(s * jnp.asarray(active)[:, None] for s in scales)
    dq = packing.dequantize_packed(stored, scales, spec)
    A = rng.random((n, n)).astype(np.float32)
    A = A / np.clip(A.sum(axis=0, keepdims=True), 1e-6, None)
    tau = rng.integers(0, 2, n).astype(np.float32)
    m = np.float32(max(1.0, (tau * active).sum()))
    return quant, spec, stored, scales, dq, jnp.asarray(A), \
        jnp.asarray(tau), jnp.asarray(active), jnp.float32(m)


def _oracle(A, tau, m, dq, active):
    """Mix the (already row-masked) dequantized buffers, aggregate with
    ``tau * active`` -- the einsum-quant recipe."""
    outs_mixed, outs_agg = [], []
    act = np.asarray(active)
    for d in dq:
        mixed = np.asarray(A) @ np.asarray(d, np.float32)
        outs_mixed.append(mixed)
        outs_agg.append(np.einsum(
            "i,ip->p", np.asarray(tau) * act, mixed) / float(m))
    return outs_mixed, outs_agg


@pytest.mark.parametrize("storage", STORAGES)
def test_dense_kernels_match_oracle(storage):
    quant, spec, stored, scales, dq, A, tau, active, m = \
        _quant_inputs(storage)
    ref_mixed, ref_agg = _oracle(A, tau, m, dq, active)
    got_mixed, got_agg = mix_aggregate_grouped_q(
        A, tau, m, stored, scales, quant=quant, chunk=512, active=active)
    for gm, ga, rm, ra in zip(got_mixed, got_agg, ref_mixed, ref_agg):
        np.testing.assert_allclose(np.asarray(gm), rm, rtol=2e-5,
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(ga), ra, rtol=2e-5,
                                   atol=2e-5)
    agg_only = aggregate_grouped_q(A, tau, m, stored, scales, quant=quant,
                                   chunk=512, active=active)
    for ga, ra in zip(agg_only, ref_agg):
        np.testing.assert_allclose(np.asarray(ga), ra, rtol=2e-5,
                                   atol=2e-5)


@pytest.mark.parametrize("storage", STORAGES)
def test_sparse_kernels_match_oracle(storage):
    quant, spec, stored, scales, dq, A, tau, active, m = \
        _quant_inputs(storage, seed=6)
    # sparsify: zero out most entries, keep ELL form of the survivors
    rng = np.random.default_rng(7)
    mask = rng.random(A.shape) < 0.4
    A = jnp.asarray(np.asarray(A) * mask, jnp.float32)
    idx_np, w_np = SparseA.from_dense(np.asarray(A)).ell()
    idx, w = jnp.asarray(idx_np), jnp.asarray(w_np)
    ref_mixed, ref_agg = _oracle(A, tau, m, dq, active)
    got_mixed, got_agg = sparse_mix_aggregate_grouped_q(
        idx, w, tau, m, stored, scales, quant=quant, chunk=512,
        active=active)
    for gm, ga, rm, ra in zip(got_mixed, got_agg, ref_mixed, ref_agg):
        np.testing.assert_allclose(np.asarray(gm), rm, rtol=2e-5,
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(ga), ra, rtol=2e-5,
                                   atol=2e-5)
    agg_only = sparse_aggregate_grouped_q(
        idx, w, tau, m, stored, scales, quant=quant, chunk=512,
        active=active)
    for ga, ra in zip(agg_only, ref_agg):
        np.testing.assert_allclose(np.asarray(ga), ra, rtol=2e-5,
                                   atol=2e-5)


# ---------------------------------------------------------------------------
# round functions: backends vs einsum-quant oracle, scan == sequential
# ---------------------------------------------------------------------------

def quad_loss(params, batch):
    x = params["x"]
    b, = batch
    return 0.5 * jnp.sum((x - b.mean(axis=0)) ** 2)


def _round_setup(seed=9, n=6, p=130, T=3, B=2):
    rng = np.random.default_rng(seed)
    batches = (jnp.asarray(rng.standard_normal((n, T, B, p)), jnp.float32),)
    A = jnp.asarray(rng.random((n, n)), jnp.float32)
    A = A / jnp.clip(A.sum(axis=0, keepdims=True), 1e-6)
    tau = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    m = jnp.float32(max(1.0, float(tau.sum())))
    return batches, A, tau, m, jnp.float32(0.1), {"x": jnp.zeros(p)}


def _qstate_for(params, n, quant):
    spec = packing.pack_spec(
        jax.tree.map(lambda p: jax.ShapeDtypeStruct((n,) + p.shape,
                                                    p.dtype), params),
        quant=quant)
    return packing.init_quant_state(spec, n)


def test_quant_backends_agree_and_share_qstate():
    batches, A, tau, m, eta, params = _round_setup()
    n = int(A.shape[0])
    quant = QuantSpec(storage="int8", block=128)
    qstate0 = _qstate_for(params, n, quant)
    idx_np, w_np = SparseA.from_dense(np.asarray(A)).ell()
    sparse_A = (jnp.asarray(idx_np), jnp.asarray(w_np))
    results = {}
    for backend in QUANT_BACKENDS:
        fn = make_round_fn(quad_loss, mixing_backend=backend, chunk=512,
                           quant=quant)
        Aarg = sparse_A if backend.startswith("sparse") else A
        new, _, qs = fn(params, batches, Aarg, tau, m, eta, None, qstate0)
        results[backend] = (np.asarray(new["x"]), qs)
    ref, ref_qs = results["einsum"]
    for backend, (got, qs) in results.items():
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5,
                                   err_msg=backend)
        # the quantizer runs before the mixing backend: state is bitwise
        # identical across all of them
        for a, b in zip(qs[0], ref_qs[0]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quant_round_fn_requires_qstate_and_valid_backend():
    with pytest.raises(ValueError, match="quantized rounds"):
        make_round_fn(quad_loss, mixing_backend="pallas",
                      quant=QuantSpec())
    with pytest.raises(ValueError, match="multiple of quant.block"):
        make_round_fn(quad_loss, mixing_backend="fused", chunk=512,
                      quant=QuantSpec(block=768))
    fn = make_round_fn(quad_loss, mixing_backend="einsum",
                       quant=QuantSpec())
    batches, A, tau, m, eta, params = _round_setup()
    with pytest.raises(ValueError, match="quantizer state"):
        fn(params, batches, A, tau, m, eta)


def test_quant_scan_matches_sequential_with_ef_carry():
    K, n, p = 4, 6, 130
    rng = np.random.default_rng(10)
    batches_seq = (jnp.asarray(
        rng.standard_normal((K, n, 3, 2, p)), jnp.float32),)
    A_seq = jnp.asarray(rng.random((K, n, n)), jnp.float32)
    A_seq = A_seq / jnp.clip(A_seq.sum(axis=1, keepdims=True), 1e-6)
    tau_seq = jnp.asarray(rng.integers(0, 2, (K, n)), jnp.float32)
    m_seq = jnp.maximum(tau_seq.sum(axis=1), 1.0)
    eta_seq = jnp.full((K,), 0.1, jnp.float32)
    params = {"x": jnp.zeros(p)}
    quant = QuantSpec(storage="int4", block=256)
    qstate0 = _qstate_for(params, n, quant)

    fn = make_round_fn(quad_loss, mixing_backend="aggregate", chunk=512,
                       quant=quant)
    seq_params, qs = params, qstate0
    for t in range(K):
        seq_params, _, qs = fn(seq_params, (batches_seq[0][t],),
                               A_seq[t], tau_seq[t], m_seq[t], eta_seq[t],
                               None, qs)
    scanned = make_scanned_rounds(quad_loss, K,
                                  mixing_backend="aggregate", chunk=512,
                                  quant=quant)
    final, params_seq, final_qs = scanned(
        params, batches_seq, A_seq, tau_seq, m_seq, eta_seq, None, qstate0)
    np.testing.assert_array_equal(np.asarray(final["x"]),
                                  np.asarray(seq_params["x"]))
    np.testing.assert_array_equal(np.asarray(params_seq["x"][-1]),
                                  np.asarray(final["x"]))
    for a, b in zip(final_qs[0], qs[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# plan serialization (JSON v5) + backend-support matrix
# ---------------------------------------------------------------------------

def _plan(t_max=3, seed=3, n=12):
    net = D2DNetwork(n=n, c=2, k_range=(4, 6), p_fail=0.1)
    cfg = ServerConfig(T=3, t_max=t_max, phi_max=0.3, seed=seed,
                       eta=lambda t: 0.2 / (1 + 0.3 * t))
    return RoundPlan.connectivity_aware(net, cfg)


def test_plan_quant_json_roundtrip_and_v4_loads():
    plan = _plan().with_quant(QuantSpec(storage="int4", block=512,
                                        error_feedback=False))
    back = RoundPlan.from_json(plan.to_json())
    assert back.quant == plan.quant
    assert back.allclose(plan)
    assert not back.allclose(plan.with_quant(None))

    # a v4 (pre-quant) payload still loads, as unquantized
    d = json.loads(plan.with_quant(None).to_json())
    assert d["version"] == 5
    d["version"] = 4
    d.pop("quant", None)
    v4 = RoundPlan.from_json(json.dumps(d))
    assert v4.quant is None


def test_plan_with_quant_validates_type():
    with pytest.raises(ValueError, match="QuantSpec"):
        _plan().with_quant({"storage": "int8"})


def test_resolve_backend_quant_matrix():
    q = QuantSpec()
    # kernel backends quantize (incl. the fused->aggregate upgrade)
    for backend in ("einsum", "fused", "aggregate", "sparse",
                    "sparse_aggregate"):
        resolve_backend(ExecutionConfig(backend=backend, quant=q))
    # pallas kept alive by record_mixed has no packed buffers
    with pytest.raises(ValueError, match="quantized rounds"):
        resolve_backend(ExecutionConfig(backend="pallas",
                                        record_mixed=True, quant=q))
    # stream runtime: no well-defined EF residual for stale cohorts
    from repro.fl import StreamConfig
    with pytest.raises(ValueError, match="stream"):
        resolve_backend(ExecutionConfig(backend="aggregate",
                                        stream=StreamConfig(), quant=q))


def test_stream_engine_rejects_plan_quant():
    from repro.fl import StreamConfig
    plan = _plan().with_quant(QuantSpec())
    cfg = ExecutionConfig(backend="aggregate", stream=StreamConfig())
    engine = make_engine(cfg, quad_loss)
    rng = np.random.default_rng(0)
    batches = [(jnp.asarray(rng.standard_normal((12, 3, 2, 4)),
                            jnp.float32),)] * plan.n_rounds
    with pytest.raises(ValueError, match="with_quant"):
        engine.execute(plan, {"x": jnp.zeros(4)}, batches)


# ---------------------------------------------------------------------------
# engine-level execution: cfg.quant and plan.quant
# ---------------------------------------------------------------------------

def _engine_run(cfg, plan=None, p=130):
    plan = plan if plan is not None else _plan()
    n = plan.n_clients
    rng = np.random.default_rng(8)
    targets = rng.standard_normal((n, p)).astype(np.float32)
    batches = [(jnp.asarray(
        targets[:, None, None, :]
        + 0.05 * rng.standard_normal((n, 3, 2, p)), jnp.float32),)
        for _ in range(plan.n_rounds)]
    engine = make_engine(cfg, quad_loss)
    params, hist = engine.execute(plan, {"x": jnp.zeros(p)}, batches)
    return np.asarray(params["x"]), hist


def test_engine_quant_sources_and_backends_agree():
    q = QuantSpec(storage="int8", block=128)
    plan = _plan()
    via_cfg, _ = _engine_run(
        ExecutionConfig(backend="aggregate", quant=q), plan)
    via_plan, _ = _engine_run(
        ExecutionConfig(backend="aggregate"), plan.with_quant(q))
    np.testing.assert_array_equal(via_cfg, via_plan)

    scanned, _ = _engine_run(
        ExecutionConfig(backend="aggregate", scan=True, quant=q), plan)
    np.testing.assert_allclose(scanned, via_cfg, rtol=1e-6, atol=1e-6)

    fused, _ = _engine_run(ExecutionConfig(backend="fused", quant=q), plan)
    np.testing.assert_allclose(fused, via_cfg, rtol=2e-5, atol=2e-5)

    fp32, _ = _engine_run(ExecutionConfig(backend="aggregate"), plan)
    assert np.abs(fp32 - via_cfg).max() > 0  # quant actually engaged


# ---------------------------------------------------------------------------
# compressed bytes: the CI gate ratios
# ---------------------------------------------------------------------------

def test_compressed_bytes_ratio_gate():
    """int4 on a bf16-majority tree and int8 on an fp32 tree both land
    at <= 0.3x the grouped full-precision wire bytes (scales included)
    -- the ratio the CI quant job asserts on the benchmark rows."""
    rng = np.random.default_rng(11)
    n = 4
    bf16_tree = {"w": jnp.asarray(rng.standard_normal((n, 4096)),
                                  jnp.bfloat16),
                 "b": jnp.asarray(rng.standard_normal((n, 256)),
                                  jnp.float32)}
    fp32_tree = {"w": jnp.asarray(rng.standard_normal((n, 4096)),
                                  jnp.float32)}
    for tree, storage in ((bf16_tree, "int4"), (fp32_tree, "int8")):
        spec = packing.pack_spec(tree)
        qspec = packing.pack_spec(tree, quant=_spec_for(storage, 512))
        ratio = qspec.quantized_nbytes(n) / spec.nbytes(n)
        assert ratio <= 0.3, (storage, ratio)
    # int8 on bf16 is only ~0.5x: the gate needs int4 there
    qspec = packing.pack_spec(bf16_tree, quant=_spec_for("int8"))
    assert packing.pack_spec(bf16_tree).nbytes(n) * 0.3 \
        < qspec.quantized_nbytes(n)


# ---------------------------------------------------------------------------
# convergence: int8+EF tracks fp32; EF-off int4 measurably diverges
# ---------------------------------------------------------------------------

def test_int8_ef_tracks_fp32_and_ef_off_int4_diverges():
    """The error-feedback claim on the quickstart workload shape: with EF
    on, int8 training lands within tolerance of the fp32 trajectory;
    dropping EF at the aggressive int4 setting loses measurably more."""
    K, n, p = 8, 6, 130
    rng = np.random.default_rng(12)
    targets = rng.standard_normal((n, p)).astype(np.float32)
    batches_seq = (jnp.asarray(
        targets[None, :, None, None, :]
        + 0.05 * rng.standard_normal((K, n, 3, 2, p)), jnp.float32),)
    A_seq = jnp.asarray(rng.random((K, n, n)), jnp.float32)
    A_seq = A_seq / jnp.clip(A_seq.sum(axis=1, keepdims=True), 1e-6)
    tau_seq = jnp.ones((K, n), jnp.float32)
    m_seq = jnp.full((K,), float(n), jnp.float32)
    eta_seq = jnp.full((K,), 0.15, jnp.float32)
    params = {"x": jnp.zeros(p)}

    def loss_of(x):
        return float(0.5 * np.mean(
            np.sum((x[None, :] - targets) ** 2, axis=1)))

    scanned = make_scanned_rounds(quad_loss, K, mixing_backend="einsum")
    fp32, _ = scanned(params, batches_seq, A_seq, tau_seq, m_seq, eta_seq)
    l_fp32 = loss_of(np.asarray(fp32["x"]))

    def run_q(quant):
        sc = make_scanned_rounds(quad_loss, K, mixing_backend="einsum",
                                 quant=quant)
        final, _, _ = sc(params, batches_seq, A_seq, tau_seq, m_seq,
                         eta_seq, None, _qstate_for(params, n, quant))
        return loss_of(np.asarray(final["x"]))

    l_int8_ef = run_q(QuantSpec(storage="int8", block=128,
                                error_feedback=True))
    l_int4_noef = run_q(QuantSpec(storage="int4", block=256,
                                  error_feedback=False))

    gap_ef = abs(l_int8_ef - l_fp32)
    gap_noef = abs(l_int4_noef - l_fp32)
    assert gap_ef <= 0.02 * max(l_fp32, 1e-6), (l_fp32, l_int8_ef)
    assert gap_noef > 5 * gap_ef, (l_fp32, l_int8_ef, l_int4_noef)
