"""repro.runtime: the wall-clock ingestion engine and its replay anchor.

The lock-down contract (ISSUE 10 acceptance criteria):

* ``RuntimeConfig(clock='virtual')`` reproduces ``StreamEngine``
  bitwise under an arbitrary seeded fault process;
* a zero-latency, fault-free wall-clock run reproduces the synchronous
  ``LocalEngine`` History bitwise, per backend;
* an overlapped wall-clock run's ``Recording`` replays bitwise through
  the virtual ``StreamEngine``, including across a JSON round-trip;
* backpressure drop policies are deterministic (tested synchronously,
  no threads, on the bare ``UploadQueue``);
* a ``wall_budget`` mid-plan shutdown still flushes a loadable
  recording whose sliced prefix verifies against the live run.

Wall-clock tests scale virtual latency down with ``time_scale`` so the
whole file stays inside tier-1 budgets; the heavier backend matrix is
``slow``-marked.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import D2DNetwork, ServerConfig
from repro.fl import (ExecutionConfig, LocalEngine, RoundPlan,
                      StreamConfig, StreamEngine, make_engine,
                      parse_fault_spec)
from repro.runtime import (IngestEngine, Recording, RuntimeConfig, Upload,
                           UploadQueue, history_digest, params_sha256)

jax.config.update("jax_enable_x64", False)


def quad_loss(params, batch):
    x = params["x"]
    b, = batch
    return 0.5 * jnp.sum((x - b.mean(axis=0)) ** 2)


def _setup(n=12, c=2, K=6, p=4, T=3, seed=3, batch_seed=7):
    net = D2DNetwork(n=n, c=c, k_range=(4, 6), p_fail=0.1)
    cfg = ServerConfig(T=T, t_max=K, phi_max=0.3, seed=seed,
                       eta=lambda t: 0.2)
    plan = RoundPlan.connectivity_aware(net, cfg)
    rng = np.random.default_rng(batch_seed)
    targets = rng.standard_normal((n, p)).astype(np.float32)
    batches = [
        (jnp.asarray(targets[:, None, None, :]
                     + 0.05 * rng.standard_normal((n, T, 2, p)),
                     jnp.float32),)
        for _ in range(K)]
    return plan, {"x": jnp.zeros(p)}, batches


FAULTY = StreamConfig(
    buffer=8, deadline=0.8, staleness="poly", max_staleness=4,
    faults=parse_fault_spec(
        "markov:p_fail=0.2,latency=exponential,mean=2.0,"
        "duplicate_rate=0.1"),
    fault_seed=5)


def _records_equal(h1, h2, check_stream=True):
    assert len(h1.records) == len(h2.records)
    for r1, r2 in zip(h1.records, h2.records):
        assert (r1.t, r1.m, r1.m_actual, r1.d2s, r1.d2d) == \
            (r2.t, r2.m, r2.m_actual, r2.d2s, r2.d2d)
        if check_stream:
            assert r1.stream == r2.stream
    assert h1.ledger.total_d2s == h2.ledger.total_d2s
    assert h1.ledger.total_d2d == h2.ledger.total_d2d


def _engine(backend, stream, runtime):
    cfg = ExecutionConfig(backend=backend, stream=stream, runtime=runtime)
    return make_engine(cfg, quad_loss)


# ---------------------------------------------------------------------------
# virtual clock: IngestEngine degenerates to StreamEngine bitwise
# ---------------------------------------------------------------------------

def test_virtual_clock_matches_stream_engine_bitwise():
    plan, params0, batches = _setup()
    e1 = _engine("einsum", FAULTY, None)
    assert isinstance(e1, StreamEngine)
    p1, h1 = e1.execute(plan, params0, batches)
    e2 = _engine("einsum", FAULTY, RuntimeConfig(clock="virtual"))
    assert isinstance(e2, IngestEngine)
    p2, h2 = e2.execute(plan, params0, batches)
    assert np.array_equal(np.asarray(p1["x"]), np.asarray(p2["x"]))
    _records_equal(h1, h2)
    # and the flushed recording is self-consistent
    rec = e2.last_recording
    assert rec.meta["rounds_done"] == plan.n_rounds
    assert rec.verify(quad_loss, params0, batches) == []


def test_virtual_clock_overlap_flag_is_inert():
    # overlap only matters on the wall clock; virtual stays bitwise
    plan, params0, batches = _setup(K=4)
    runs = []
    for overlap in (True, False):
        e = _engine("einsum", FAULTY,
                    RuntimeConfig(clock="virtual", overlap=overlap))
        runs.append(e.execute(plan, params0, batches))
    assert np.array_equal(np.asarray(runs[0][0]["x"]),
                          np.asarray(runs[1][0]["x"]))
    _records_equal(runs[0][1], runs[1][1])


# ---------------------------------------------------------------------------
# zero-latency wall clock == synchronous LocalEngine, per backend
# ---------------------------------------------------------------------------

def _zero_latency_wall(backend):
    plan, params0, batches = _setup(K=4)
    pl, hl = LocalEngine(quad_loss, ExecutionConfig(backend=backend)) \
        .execute(plan, params0, batches)
    e = _engine(backend, StreamConfig(), RuntimeConfig(
        clock="wall", time_scale=0.02, workers=4))
    pw, hw = e.execute(plan, params0, batches)
    assert np.array_equal(np.asarray(pl["x"]), np.asarray(pw["x"]))
    _records_equal(hl, hw, check_stream=False)
    assert e.last_recording.plan.source == "measured"


def test_zero_latency_wall_matches_local_engine():
    _zero_latency_wall("einsum")


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["fused", "aggregate"])
def test_zero_latency_wall_matches_local_engine_packed(backend):
    _zero_latency_wall(backend)


# ---------------------------------------------------------------------------
# the anchor: overlapped wall-clock runs replay bitwise from recordings
# ---------------------------------------------------------------------------

def _verify_wall_run(runtime, backend="einsum", stream=FAULTY, K=6):
    plan, params0, batches = _setup(K=K)
    e = _engine(backend, stream, runtime)
    p_live, h_live = e.execute(plan, params0, batches)
    rec = e.last_recording
    assert rec.meta["history"] == history_digest(h_live)
    assert rec.meta["params_sha256"] == params_sha256(p_live)
    assert rec.verify(quad_loss, params0, batches, backend=backend) == []
    # the artifact survives serialization
    rt = Recording.from_json(rec.to_json())
    assert rt.verify(quad_loss, params0, batches, backend=backend) == []
    return rec


def test_overlapped_wall_recording_replays_bitwise():
    rec = _verify_wall_run(RuntimeConfig(clock="wall", time_scale=0.02,
                                         workers=4, overlap=True))
    assert rec.meta["overlap"] is True
    assert rec.meta["clock"] == "wall"
    # the wall run measured real offsets: some upload arrived at a
    # non-planned (measured) position
    assert rec.plan.source == "measured"


def test_non_overlapped_wall_recording_replays_bitwise():
    _verify_wall_run(RuntimeConfig(clock="wall", time_scale=0.02,
                                   workers=4, overlap=False))


@pytest.mark.slow
def test_wall_recording_replays_across_backends():
    # record under the packed backend, verify the replay on einsum too:
    # the recording pins traffic, not the mixing implementation
    plan, params0, batches = _setup(K=4)
    e = _engine("aggregate", FAULTY,
                RuntimeConfig(clock="wall", time_scale=0.02))
    e.execute(plan, params0, batches)
    rec = e.last_recording
    assert rec.verify(quad_loss, params0, batches,
                      backend="aggregate") == []


# ---------------------------------------------------------------------------
# backpressure: drop policies, synchronously (no threads)
# ---------------------------------------------------------------------------

def _uploads(k):
    return [Upload(round=0, client=i, wall=float(i)) for i in range(k)]


def test_queue_drop_oldest_is_deterministic():
    q = UploadQueue(capacity=3, policy="drop_oldest")
    for u in _uploads(5):
        assert q.put(u) is True
    landed, dropped = q.drain()
    assert [u.client for u in landed] == [2, 3, 4]
    assert [u.client for u in dropped] == [0, 1]
    # drained clean: nothing left
    assert q.drain() == ([], [])


def test_queue_reject_is_deterministic():
    q = UploadQueue(capacity=3, policy="reject")
    results = [q.put(u) for u in _uploads(5)]
    assert results == [True, True, True, False, False]
    landed, dropped = q.drain()
    assert [u.client for u in landed] == [0, 1, 2]
    assert [u.client for u in dropped] == [3, 4]


def test_queue_force_put_bypasses_capacity():
    q = UploadQueue(capacity=1, policy="reject")
    assert q.put(_uploads(1)[0]) is True
    assert q.put(Upload(0, 9, 9.0), force=True) is True
    landed, dropped = q.drain()
    assert [u.client for u in landed] == [0, 9] and dropped == []


def test_queue_close_unblocks_block_policy():
    q = UploadQueue(capacity=1, policy="block")
    q.put(Upload(0, 0, 0.0))
    q.close()
    # would deadlock without close(); falls through and over-fills
    assert q.put(Upload(0, 1, 1.0)) is True
    assert len(q) == 2


def test_queue_seeded_load_is_reproducible():
    def run(policy):
        rng = np.random.default_rng(42)
        q = UploadQueue(capacity=4, policy=policy)
        for k in range(40):
            q.put(Upload(int(rng.integers(4)), int(rng.integers(12)),
                         float(k)))
            if rng.random() < 0.3:
                q.drain()
        landed, dropped = q.drain()
        return ([(u.round, u.client) for u in landed],
                [(u.round, u.client) for u in dropped])

    for policy in ("drop_oldest", "reject"):
        assert run(policy) == run(policy)


def test_wall_run_with_reject_policy_itemizes_drops():
    # capacity 1 under bursty traffic: drops happen, are itemized, and
    # the run still completes every round (History documents the loss;
    # the live-vs-replay billing-round divergence is documented in
    # repro.runtime.queueing, so no bitwise verify here)
    plan, params0, batches = _setup(K=5)
    e = _engine("einsum", FAULTY, RuntimeConfig(
        clock="wall", time_scale=0.02, queue_capacity=1,
        drop_policy="reject"))
    _, h = e.execute(plan, params0, batches)
    assert len(h.records) == plan.n_rounds
    rec = e.last_recording
    for r, i in rec.meta["drops"]:
        assert 0 <= r < plan.n_rounds and 0 <= i < plan.n_clients
        # a dropped upload never lands: its measured arrival stays inf
        assert math.isinf(float(np.asarray(rec.plan.arrival_t)[r, i]))


# ---------------------------------------------------------------------------
# graceful shutdown: wall_budget flushes a loadable, verifiable prefix
# ---------------------------------------------------------------------------

def test_wall_budget_shutdown_flushes_loadable_recording(tmp_path):
    plan, params0, batches = _setup(K=40)
    e = _engine("einsum", FAULTY, RuntimeConfig(
        clock="wall", time_scale=0.05, wall_budget=0.6))
    _, h = e.execute(plan, params0, batches)
    done = len(h.records)
    assert 0 < done < plan.n_rounds, "budget should stop mid-plan"
    rec = e.last_recording
    assert rec.meta["rounds_done"] == done
    assert rec.plan.n_rounds == done
    path = tmp_path / "rec.json"
    rec.save(str(path))
    loaded = Recording.load(str(path))
    assert loaded.verify(quad_loss, params0, batches) == []


# ---------------------------------------------------------------------------
# config wiring
# ---------------------------------------------------------------------------

def test_runtime_config_validation():
    with pytest.raises(ValueError):
        RuntimeConfig(clock="sundial")
    with pytest.raises(ValueError):
        RuntimeConfig(time_scale=0.0)
    with pytest.raises(ValueError):
        RuntimeConfig(workers=0)
    with pytest.raises(ValueError):
        RuntimeConfig(queue_capacity=0)
    with pytest.raises(ValueError):
        RuntimeConfig(drop_policy="shred")
    with pytest.raises(ValueError):
        RuntimeConfig(wall_budget=0.0)


def test_runtime_requires_stream_config():
    with pytest.raises(ValueError, match="stream"):
        make_engine(ExecutionConfig(runtime=RuntimeConfig()), quad_loss)


def test_ingest_engine_rejects_trace_kwarg():
    plan, params0, batches = _setup(K=2)
    e = _engine("einsum", StreamConfig(),
                RuntimeConfig(clock="virtual"))
    from repro.fl.faults import sample_trace, FaultSpec
    trace = sample_trace(FaultSpec(), plan.n_clients, plan.n_rounds,
                         seed=0)
    with pytest.raises(ValueError, match="replay"):
        e.execute(plan, params0, batches, trace=trace)
