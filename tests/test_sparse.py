"""Sparse representations and kernels (sparse-ClusterGraph tentpole).

Covers the containers (``SparseClusterGraph``, ``SparseA``,
``SparseAseq``), the O(nnz) equal-neighbor assembly
(``network_matrix_sparse`` vs the dense ``network_matrix`` oracle), the
``sample_sparse`` topology path (every family, dense == densified
sparse, identical rng streams), the ELL Pallas kernels vs the dense
kernels, and the satellite regressions that ride along:

* ``KRegular`` degree clamp at tiny cluster sizes with
  ``self_loops=False`` (a union of shift permutations has only ``s - 1``
  non-self targets);
* the ``self_loops=False`` policy surviving the
  ``ensure_positive_out_degree`` repair in every family;
* the shared ``m == 0`` safe-divide in ``combine_weights`` /
  ``combine_weights_ell``.

Kernel parity is allclose, not bitwise: the unrolled ELL gather loop
accumulates in neighbor order while the dense MXU matmul reduces over
all n; both accumulate in fp32, so at these sizes 1e-5 absolute is a
generous bound on the reordering error.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro import topology
from repro.core.adjacency import network_matrix, network_matrix_sparse
from repro.core.graphs import (ClusterGraph, SparseClusterGraph,
                               degree_stats, degree_stats_from_arrays,
                               ensure_positive_out_degree)
from repro.core.metrics import count_d2d_transmissions
from repro.core.sparse import SparseA, SparseAseq, ell_from_dense
from repro.kernels.mixing import ops

ALL_FAMILIES = topology.families()


def _random_A(rng, n, max_deg=4):
    """A random sparse nonnegative matrix with >= 1 entry per row."""
    A = np.zeros((n, n), np.float32)
    for i in range(n):
        nbrs = rng.choice(n, size=rng.integers(1, max_deg + 1),
                          replace=False)
        A[i, nbrs] = rng.random(len(nbrs)).astype(np.float32) + 0.1
    return A


# ---------------------------------------------------------------------------
# containers
# ---------------------------------------------------------------------------


def test_sparse_cluster_graph_round_trip_and_stats():
    rng = np.random.default_rng(0)
    W = (rng.random((7, 7)) < 0.4).astype(np.int8)
    np.fill_diagonal(W, 1)
    verts = np.arange(10, 17)
    g = SparseClusterGraph.from_dense(verts, W)
    assert g.size == 7
    assert np.array_equal(g.dense().W, W)
    assert np.array_equal(g.W, W)
    assert np.array_equal(g.d_out, W.sum(axis=1))
    assert np.array_equal(g.d_in, W.sum(axis=0))
    assert g.d2d_transmissions == count_d2d_transmissions(W)
    # degree-only stats match the dense densify-then-count path
    assert g.stats == degree_stats(W)


def test_degree_stats_from_arrays_rejects_dead_rows():
    with pytest.raises(ValueError):
        degree_stats_from_arrays(np.array([2, 0, 1]), np.array([1, 1, 1]))


def test_sparse_a_round_trips_and_ell_padding():
    rng = np.random.default_rng(1)
    A = _random_A(rng, 9)
    sp = SparseA.from_dense(A)
    assert sp.nnz == (A != 0).sum()
    assert np.array_equal(sp.dense(), A)
    idx, w = sp.ell()
    assert idx.shape == w.shape == (9, int(sp.row_degrees.max()))
    # ELL reconstructs the matrix: scatter each slot back
    back = np.zeros_like(A)
    for i in range(9):
        for k in range(idx.shape[1]):
            back[i, idx[i, k]] += w[i, k]
    assert np.allclose(back, A)
    # padding slots are index 0 / weight 0.0 (the no-op convention)
    deg = sp.row_degrees
    for i in range(9):
        assert (w[i, deg[i]:] == 0.0).all()
        assert (idx[i, deg[i]:] == 0).all()
    # edge-list assembly canonicalizes to the same CSR
    dst, src = np.nonzero(A)
    perm = rng.permutation(len(dst))
    again = SparseA.from_edges(9, dst[perm], src[perm],
                               A[dst, src][perm])
    assert again.equals(sp)
    ei, ew = ell_from_dense(A)
    assert np.array_equal(ei, idx) and np.array_equal(ew, w)


def test_sparse_a_identity_is_fedavg_matrix():
    sp = SparseA.identity(5)
    assert sp.nnz == 5
    assert np.array_equal(sp.dense(), np.eye(5, dtype=np.float32))


def test_sparse_aseq_surface_and_shared_dmax():
    rng = np.random.default_rng(2)
    A_t = np.stack([_random_A(rng, 6, max_deg=k + 1) for k in range(3)])
    seq = SparseAseq.from_dense(A_t)
    assert seq.shape == (3, 6, 6)
    assert len(seq) == 3
    assert np.array_equal(seq.dense(), A_t)
    assert isinstance(seq[1], SparseA)
    sub = seq[1:]
    assert isinstance(sub, SparseAseq) and len(sub) == 2
    idx, w = seq.ell()
    # one shared d_max across rounds (scan shape stability)
    assert idx.shape == w.shape == (3, 6, seq.max_degree)


# ---------------------------------------------------------------------------
# equal-neighbor assembly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", sorted(ALL_FAMILIES))
def test_network_matrix_sparse_matches_dense(family):
    n, c = 24, 3
    model = topology.make_spec(family, n=n, c=c).build()
    rng = np.random.default_rng(7)
    clusters = model.sample_sparse(rng, 0)
    A_sp = network_matrix_sparse(clusters, n)
    A_dn = network_matrix([g.dense() for g in clusters], n)
    assert np.allclose(A_sp.dense(), A_dn, atol=1e-7)


def test_network_matrix_sparse_rejects_dead_out_degree():
    g = SparseClusterGraph(vertices=np.array([0, 1]),
                           indptr=np.array([0, 1, 1], np.int64),
                           indices=np.array([1], np.int32))
    with pytest.raises(ValueError, match="out-degree"):
        network_matrix_sparse([g], 2)


# ---------------------------------------------------------------------------
# sample_sparse across families (and the satellite family fixes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", sorted(ALL_FAMILIES))
@pytest.mark.parametrize("self_loops", [True, False])
def test_sample_sparse_matches_sample(family, self_loops):
    """Dense snapshots derive from sparse ones (identical rng stream),
    for every family, with and without self-loops."""
    n, c = 30, 3
    spec = topology.make_spec(family, n=n, c=c, self_loops=self_loops)
    sparse = spec.build().sample_sparse(np.random.default_rng(3), 0)
    dense = spec.build().sample(np.random.default_rng(3), 0)
    for g_sp, g_dn in zip(sparse, dense):
        assert np.array_equal(g_sp.vertices, g_dn.vertices)
        assert np.array_equal(g_sp.dense().W, g_dn.W)


@pytest.mark.parametrize("family", sorted(ALL_FAMILIES))
def test_self_loops_false_is_honored(family):
    """No family silently reintroduces a self-loop when
    ``self_loops=False`` (satellite: the ``ensure_positive_out_degree``
    fallback used to).  Singleton clusters are the documented exception:
    a positive out-degree forces the self-loop there."""
    for n, c in [(2, 1), (3, 1), (12, 3), (30, 3)]:
        model = topology.make_spec(family, n=n, c=c,
                                   self_loops=False).build()
        rng = np.random.default_rng(11)
        for t in range(3):
            for g in model.sample_sparse(rng, t):
                W = g.dense().W
                assert (W.sum(axis=1) > 0).all(), (family, n, t)
                if g.size > 1:
                    assert np.trace(W) == 0, (family, n, t)


@pytest.mark.parametrize("s", [1, 2, 3])
@pytest.mark.parametrize("self_loops", [True, False])
def test_k_regular_tiny_clusters(s, self_loops):
    """Satellite regression: ``k_range`` far above the cluster size must
    clamp to a feasible degree -- with ``self_loops=False`` the max is
    ``s - 1`` (shift 0 is forbidden), which the old ``min(k, s)`` clamp
    exceeded, raising inside ``k_regular_digraph``."""
    model = topology.make_spec("k_regular", n=s, c=1,
                               k_range=(6, 7, 8, 9), p_fail=0.0,
                               self_loops=self_loops).build()
    rng = np.random.default_rng(0)
    (g,) = model.sample(rng, 0)
    W = g.W
    assert (W.sum(axis=1) > 0).all()
    if not self_loops and s > 1:
        assert np.trace(W) == 0
        assert (W.sum(axis=1) == s - 1).all()


def test_ensure_positive_out_degree_self_loop_policy():
    W = np.zeros((4, 4), np.int8)
    W[0, 1] = 1
    repaired = ensure_positive_out_degree(W, self_loops=False)
    assert (repaired.sum(axis=1) > 0).all()
    assert np.trace(repaired) == 0         # non-self repair edges
    # default path unchanged (bitwise-compatible with history)
    legacy = ensure_positive_out_degree(W)
    assert np.trace(legacy) == 3
    # singleton: the self-loop is the only possible edge
    one = ensure_positive_out_degree(np.zeros((1, 1), np.int8),
                                     self_loops=False)
    assert one[0, 0] == 1


@pytest.mark.parametrize("family", ["ring", "hub"])
def test_native_cluster_sparse_matches_cluster_w(family):
    """Ring and Hub emit CSR natively (no (s, s) scratch); pinned equal
    to the dense ``_cluster_W`` construction."""
    for self_loops in (True, False):
        for s in (1, 2, 3, 8):
            model = topology.make_spec(family, n=s, c=1,
                                       self_loops=self_loops).build()
            rng = np.random.default_rng(5)
            verts = np.arange(s)
            g_sp = model._cluster_sparse(rng, 0, verts)
            W = model._cluster_W(np.random.default_rng(5), 0, verts)
            assert np.array_equal(g_sp.dense().W, W), (family, self_loops,
                                                       s)


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------


def _kernel_inputs(n=13, p=37, seed=0):
    rng = np.random.default_rng(seed)
    A = _random_A(rng, n)
    idx, w = SparseA.from_dense(A).ell()
    X = rng.standard_normal((n, p)).astype(np.float32)
    tau = (rng.random(n) < 0.6).astype(np.float32)
    active = (rng.random(n) < 0.8).astype(np.float32)
    weights = rng.random(n).astype(np.float32)
    return (jnp.asarray(A), jnp.asarray(idx), jnp.asarray(w),
            jnp.asarray(X), jnp.asarray(tau), jnp.asarray(active),
            jnp.asarray(weights))


def test_sparse_mix_matches_dense():
    A, idx, w, X, *_ = _kernel_inputs()
    dense = ops.mix(A, X, chunk=128)
    sparse = ops.sparse_mix(idx, w, X, chunk=128)
    assert np.allclose(dense, sparse, atol=1e-5)


@pytest.mark.parametrize("masked", [False, True])
def test_sparse_mix_aggregate_matches_dense(masked):
    A, idx, w, X, tau, active, weights = _kernel_inputs(seed=masked)
    kw = (dict(active=active, weights=weights) if masked else {})
    m = jnp.float32(float(np.asarray(tau).sum()) or 1.0)
    dm, da = ops.mix_aggregate(A, tau, m, X, chunk=128, **kw)
    sm, sa = ops.sparse_mix_aggregate(idx, w, tau, m, X, chunk=128, **kw)
    assert np.allclose(dm, sm, atol=1e-5)
    assert np.allclose(da, sa, atol=1e-5)
    # aggregate-only path agrees with the fused row
    sa2 = ops.sparse_aggregate(idx, w, tau, m, X, chunk=128, **kw)
    assert np.allclose(da, sa2, atol=1e-5)


def test_combine_weights_ell_matches_dense():
    A, idx, w, X, tau, active, weights = _kernel_inputs(seed=3)
    m = jnp.float32(3.0)
    dense = ops.combine_weights(A, tau, m, active, weights)
    sparse = ops.combine_weights_ell(idx, w, tau, m, active, weights)
    assert np.allclose(dense, sparse, atol=1e-6)


def test_combine_weights_m_zero_guard():
    """Satellite regression: an all-dropped round (m == 0) must yield
    the zero combine row, not inf/nan -- and the guard must be inert for
    m != 0 (identical to the unguarded divide)."""
    A, idx, w, X, tau, active, weights = _kernel_inputs(seed=4)
    for fn, args in ((ops.combine_weights, (A,)),
                     (ops.combine_weights_ell, (idx, w))):
        row = np.asarray(fn(*args, tau, jnp.float32(0.0)))
        assert (row == 0.0).all() and np.isfinite(row).all()
    # inert for m != 0: exactly einsum / m
    got = np.asarray(ops.combine_weights(A, tau, jnp.float32(5.0)))
    ref = np.einsum("i,ij->j", np.asarray(tau, np.float32),
                    np.asarray(A, np.float32)) / np.float32(5.0)
    assert np.allclose(got, ref, atol=0, rtol=1e-6)


def test_m_zero_guard_through_round():
    """The guard holds end to end: aggregate with m = 0 returns the
    zero row, so the global update degenerates to identity."""
    A, idx, w, X, tau, active, weights = _kernel_inputs(seed=5)
    agg = ops.sparse_aggregate(idx, w, tau, jnp.float32(0.0), X,
                               chunk=128)
    assert (np.asarray(agg) == 0.0).all()
    agg_d = ops.aggregate(A, tau, jnp.float32(0.0), X, chunk=128)
    assert (np.asarray(agg_d) == 0.0).all()
