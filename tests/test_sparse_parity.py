"""Cross-representation parity: sparse plans/backends vs the dense oracle.

The tentpole acceptance sweep: for every registered family x dropout
model (iid / markov / cluster) x ``with_faults``, the sparse-planned,
sparse-executed trajectory matches the dense-planned, einsum-executed
one at History level (same bookkeeping bitwise, same final params to
fp32-reduction tolerance).  Plus the serialization contract (JSON v4
CSR encoding round-trips; v3 dense payloads still load), resume slicing
on sparse plans, and the scale acceptance: an n = 100_000 plan builds,
serializes, and executes one round without ever materializing an
(n, n) array -- at that size a single dense A_t round would be 40 GB,
so this test *completing* is the proof.
"""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro import topology
from repro.core.server import ServerConfig
from repro.core.sparse import SparseAseq
from repro.fl import ExecutionConfig, RoundPlan, make_engine
from repro.fl.engine import resolve_backend
from repro.fl.faults import FaultSpec, sample_trace

ALL_FAMILIES = sorted(topology.families())
DROPOUTS = ("iid", "markov", "cluster")


def quad_loss(params, batch):
    b, = batch
    return 0.5 * jnp.sum((params["x"] - b.mean(axis=0)) ** 2)


def _batches(n, rounds, p=3, T=2, B=2, seed=0):
    rng = np.random.default_rng(seed)
    return [(jnp.asarray(rng.standard_normal((n, T, B, p)), jnp.float32),)
            for _ in range(rounds)]


def _params(p=3):
    return {"x": jnp.zeros((p,), jnp.float32)}


def _plans(family, dropout, n=18, c=3, K=3, seed=9):
    """(dense, sparse) plans with identical columns: same spec, same
    seed, the same dropout transform, the same fault trace."""
    cfg = ServerConfig(T=2, t_max=K, m0=max(2, n // 3), seed=seed)
    pair = []
    for sparse in (False, True):
        model = topology.make_spec(family, n=n, c=c).build()
        plan = RoundPlan.connectivity_aware(model, cfg, sparse=sparse)
        rng = np.random.default_rng(seed + 1)
        if dropout == "iid":
            plan = plan.with_dropout(0.25, rng)
        elif dropout == "markov":
            plan = plan.with_markov_dropout(0.3, 0.5, rng)
        else:
            plan = plan.with_cluster_dropout(0.3, rng)
        trace = sample_trace(FaultSpec(failures="iid",
                                       failure_params={"rate": 0.2}),
                             n=n, K=K, seed=seed + 2)
        pair.append(plan.with_faults(trace))
    return pair


def _history_rows(history):
    return [(r.t, r.m, r.m_actual, r.d2s, r.d2d) for r in history.records]


@pytest.mark.parametrize("family", ALL_FAMILIES)
@pytest.mark.parametrize("dropout", DROPOUTS)
def test_sparse_backend_matches_dense_oracle(family, dropout):
    dense_plan, sparse_plan = _plans(family, dropout)
    # planning parity first: every non-A column bitwise, A values equal
    assert np.array_equal(dense_plan.tau_t, sparse_plan.tau_t)
    assert np.array_equal(dense_plan.active_t, sparse_plan.active_t)
    assert np.array_equal(dense_plan.m_t, sparse_plan.m_t)
    assert np.array_equal(dense_plan.d2d_t, sparse_plan.d2d_t)
    assert np.array_equal(dense_plan.psi_bound_t, sparse_plan.psi_bound_t)
    assert np.array_equal(dense_plan.A_t, sparse_plan.A_t.dense())

    n, K = dense_plan.n_clients, dense_plan.n_rounds
    batches = _batches(n, K)
    oracle = make_engine(ExecutionConfig(backend="einsum"), quad_loss)
    fd, hd = oracle.execute(dense_plan, _params(), batches)
    eng = make_engine(ExecutionConfig(backend="sparse", chunk=128),
                      quad_loss)
    fs, hs = eng.execute(sparse_plan, _params(), batches)
    # History bookkeeping is planning data: bitwise
    assert _history_rows(hd) == _history_rows(hs)
    # final params: fp32 reduction-order tolerance (see test_sparse.py)
    np.testing.assert_allclose(np.asarray(fd["x"]), np.asarray(fs["x"]),
                               atol=1e-5)


def test_sparse_scan_matches_sequential():
    _, plan = _plans("k_regular", "markov")
    n, K = plan.n_clients, plan.n_rounds
    batches = _batches(n, K)
    outs = []
    for scan in (False, True):
        eng = make_engine(
            ExecutionConfig(backend="sparse", scan=scan, chunk=128),
            quad_loss)
        f, _ = eng.execute(plan, _params(), batches)
        outs.append(np.asarray(f["x"]))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_record_mixed_upgrade_matrix():
    assert resolve_backend(
        ExecutionConfig(backend="sparse")) == "sparse_aggregate"
    assert resolve_backend(
        ExecutionConfig(backend="sparse", record_mixed=True)) == "sparse"
    with pytest.raises(ValueError, match="record_mixed"):
        resolve_backend(ExecutionConfig(backend="sparse_aggregate",
                                        record_mixed=True))


def test_stream_rejects_sparse_backends():
    from repro.fl.stream import StreamConfig
    with pytest.raises(ValueError, match="stream"):
        resolve_backend(ExecutionConfig(backend="sparse",
                                        stream=StreamConfig()))


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------


def test_json_csr_round_trip():
    _, plan = _plans("erdos_renyi", "iid")
    text = plan.to_json()
    payload = json.loads(text)
    assert payload["version"] == 5      # v4 added CSR; v5 added quant
    assert payload["A_t"]["encoding"] == "csr"
    back = RoundPlan.from_json(text)
    assert back.is_sparse
    assert back.allclose(plan)


def test_json_v4_payload_still_loads():
    """A pre-quant (v4) payload loads as an unquantized plan."""
    _, plan = _plans("erdos_renyi", "iid")
    payload = json.loads(plan.to_json())
    payload["version"] = 4
    payload.pop("quant", None)
    back = RoundPlan.from_json(json.dumps(payload))
    assert back.is_sparse and back.quant is None
    assert back.allclose(plan)


def test_json_v3_dense_payload_still_loads():
    dense_plan, _ = _plans("erdos_renyi", "iid")
    payload = json.loads(dense_plan.to_json())
    assert not isinstance(payload["A_t"], dict)   # dense keeps v3 layout
    payload["version"] = 3
    back = RoundPlan.from_json(json.dumps(payload))
    assert not back.is_sparse
    assert back.allclose(dense_plan)


def test_json_rejects_unknown_encoding():
    _, plan = _plans("ring", "iid")
    payload = json.loads(plan.to_json())
    payload["A_t"]["encoding"] = "coo"
    with pytest.raises(ValueError, match="encoding"):
        RoundPlan.from_json(json.dumps(payload))


def test_sparsify_densify_round_trip_is_bitwise():
    dense_plan, sparse_plan = _plans("small_world", "cluster")
    assert dense_plan.sparsify().densify().allclose(dense_plan)
    assert sparse_plan.densify().sparsify().allclose(sparse_plan)
    # representation is part of identity
    assert not dense_plan.allclose(sparse_plan)
    assert dense_plan.sparsify().allclose(sparse_plan)


def test_sparse_regenerate_is_bitwise():
    model = topology.make_spec("geometric", n=20, c=4).build()
    cfg = ServerConfig(T=2, t_max=4, m0=6, seed=13)
    plan = RoundPlan.connectivity_aware(model, cfg, sparse=True)
    again = plan.regenerate()
    assert again.is_sparse
    assert again.allclose(plan)


# ---------------------------------------------------------------------------
# resume slicing (satellite: step guard + tail-resume coverage)
# ---------------------------------------------------------------------------


def test_sparse_plan_slice_resume():
    _, plan = _plans("hub", "iid")
    tail = plan[1:]
    assert tail.is_sparse and tail.t0 == 1
    assert tail.n_rounds == plan.n_rounds - 1
    assert np.array_equal(tail.A_t.dense(), plan.A_t.dense()[1:])
    # executing the tail resumes with global round indices
    batches = _batches(plan.n_clients, plan.n_rounds)
    eng = make_engine(ExecutionConfig(backend="sparse", chunk=128),
                      quad_loss)
    full, h_full = eng.execute(plan, _params(), batches)
    mid, _ = eng.execute(plan[:1], _params(), batches[:1])
    resumed, h_tail = eng.execute(tail, {k: jnp.asarray(v)
                                         for k, v in mid.items()},
                                  batches[1:])
    np.testing.assert_array_equal(np.asarray(full["x"]),
                                  np.asarray(resumed["x"]))
    assert [r.t for r in h_tail.records] \
        == [r.t for r in h_full.records][1:]


@pytest.mark.parametrize("sparse", [False, True])
def test_plan_slice_step_guard(sparse):
    plan, plan_sp = _plans("ring", "iid")
    plan = plan_sp if sparse else plan
    for sl in (slice(None, None, 2), slice(2, None, -1),
               slice(None, None, 0)):
        with pytest.raises(ValueError, match="step"):
            plan[sl]
    # step None and step 1 are both fine
    assert plan[::].n_rounds == plan.n_rounds
    assert plan[0:2:1].n_rounds == 2


# ---------------------------------------------------------------------------
# scale acceptance
# ---------------------------------------------------------------------------


def test_sparse_plan_scales_to_100k_clients():
    """The headline: n = 100_000 (12_500 ring clusters of 8) plans,
    serializes, round-trips, and executes one round on the sparse
    backend.  A dense A_t would be 40 GB; completion at test speed is
    the no-(n, n)-allocation proof."""
    n, c = 100_000, 12_500
    model = topology.make_spec("ring", n=n, c=c, hops=1).build()
    cfg = ServerConfig(T=1, t_max=1, m0=n // 10, seed=0,
                       bound_kind="general")
    plan = RoundPlan.connectivity_aware(model, cfg, sparse=True)
    assert plan.is_sparse
    assert isinstance(plan.A_t, SparseAseq)
    assert plan.A_t.nnz == 2 * n          # ring: self-loop + successor
    assert plan.A_t.max_degree == 2
    text = plan.to_json()
    assert RoundPlan.from_json(text).allclose(plan)

    rng = np.random.default_rng(0)
    batches = [(jnp.asarray(rng.standard_normal((n, 1, 1, 3)),
                            jnp.float32),)]
    eng = make_engine(ExecutionConfig(backend="sparse", chunk=128),
                      quad_loss)
    final, history = eng.execute(plan, _params(), batches)
    assert np.isfinite(np.asarray(final["x"])).all()
    assert len(history.records) == 1
    assert history.records[0].d2d == n    # one non-self edge per client
