"""StreamEngine: bitwise synchronous equivalence, staleness-weight
degeneracy, fault replay, graceful degradation, and the backend matrix.

The lock-down contract (ISSUE 6 acceptance criteria):

* zero staleness + full buffer + no faults reproduces the synchronous
  ``LocalEngine`` History bitwise, per backend;
* any seeded ``FaultSpec`` trajectory replays bitwise from its JSON
  round-trip;
* a zero-latency fault trace streamed semi-asynchronously equals the
  synchronous engine on ``plan.with_faults(trace)`` bitwise.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import D2DNetwork, FederatedServer, ServerConfig
from repro.fl import (ExecutionConfig, FaultSpec, LocalEngine, RoundPlan,
                      StreamConfig, StreamEngine, make_engine,
                      resolve_backend, sample_trace, staleness_weight)
from repro.kernels.mixing.ops import combine_weights

jax.config.update("jax_enable_x64", False)

STREAM_BACKENDS = ("einsum", "fused", "aggregate")


def quad_loss(params, batch):
    x = params["x"]
    b, = batch
    return 0.5 * jnp.sum((x - b.mean(axis=0)) ** 2)


def _setup(n=12, c=2, K=6, p=4, T=3, seed=3, batch_seed=7):
    net = D2DNetwork(n=n, c=c, k_range=(4, 6), p_fail=0.1)
    cfg = ServerConfig(T=T, t_max=K, phi_max=0.3, seed=seed,
                       eta=lambda t: 0.2)
    plan = RoundPlan.connectivity_aware(net, cfg)
    rng = np.random.default_rng(batch_seed)
    targets = rng.standard_normal((n, p)).astype(np.float32)
    batches = [
        (jnp.asarray(targets[:, None, None, :]
                     + 0.05 * rng.standard_normal((n, T, 2, p)),
                     jnp.float32),)
        for _ in range(K)]
    return plan, {"x": jnp.zeros(p)}, batches


def _eval(prm):
    return {"l2": float(jnp.sum(prm["x"] ** 2))}


def _records_equal(h1, h2, check_stream=True):
    assert len(h1.records) == len(h2.records)
    for r1, r2 in zip(h1.records, h2.records):
        assert (r1.t, r1.m, r1.m_actual, r1.d2s, r1.d2d) == \
            (r2.t, r2.m, r2.m_actual, r2.d2s, r2.d2d)
        assert r1.eta == r2.eta
        assert r1.psi_bound == r2.psi_bound or (
            math.isnan(r1.psi_bound) and math.isnan(r2.psi_bound))
        assert r1.metrics == r2.metrics
        if check_stream:
            assert r1.stream == r2.stream
    assert h1.ledger.total_d2s == h2.ledger.total_d2s
    assert h1.ledger.total_d2d == h2.ledger.total_d2d


# ---------------------------------------------------------------------------
# Bitwise equivalence with the synchronous engine (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", STREAM_BACKENDS)
def test_no_fault_stream_reproduces_local_engine_bitwise(backend):
    plan, params0, batches = _setup()
    p1, h1 = LocalEngine(quad_loss, ExecutionConfig(backend=backend)) \
        .execute(plan, params0, batches, eval_fn=_eval)
    p2, h2 = make_engine(
        ExecutionConfig(backend=backend, stream=StreamConfig()),
        quad_loss).execute(plan, params0, batches, eval_fn=_eval)
    np.testing.assert_array_equal(np.asarray(p1["x"]), np.asarray(p2["x"]))
    assert all(r.stream is None for r in h2.records)
    _records_equal(h1, h2, check_stream=False)


@pytest.mark.parametrize("backend", STREAM_BACKENDS)
def test_full_buffer_zero_latency_equals_sync(backend):
    """Satellite: b = n with zero latency is the synchronous round."""
    plan, params0, batches = _setup()
    p1, _ = LocalEngine(quad_loss, ExecutionConfig(backend=backend)) \
        .execute(plan, params0, batches)
    p2, h2 = make_engine(
        ExecutionConfig(backend=backend,
                        stream=StreamConfig(buffer=plan.n_clients)),
        quad_loss).execute(plan, params0, batches)
    np.testing.assert_array_equal(np.asarray(p1["x"]), np.asarray(p2["x"]))
    assert all(r.stream is None for r in h2.records)


def test_dropout_plan_no_latency_stream_matches_local():
    """Straggler masks flow through the stream fast path bitwise."""
    plan, params0, batches = _setup()
    plan = plan.with_dropout(0.3, np.random.default_rng(5))
    p1, h1 = LocalEngine(quad_loss, ExecutionConfig()) \
        .execute(plan, params0, batches, eval_fn=_eval)
    p2, h2 = make_engine(ExecutionConfig(stream=StreamConfig()),
                         quad_loss) \
        .execute(plan, params0, batches, eval_fn=_eval)
    np.testing.assert_array_equal(np.asarray(p1["x"]), np.asarray(p2["x"]))
    _records_equal(h1, h2, check_stream=False)


def test_zero_latency_faults_equal_with_faults_local_run():
    """Failure chains with no latency reduce to plan straggler masks:
    the stream run and the synchronous run on plan.with_faults(trace)
    are bitwise-identical."""
    plan, params0, batches = _setup()
    spec = FaultSpec(failures="markov",
                     failure_params={"p_fail": 0.3, "p_recover": 0.5})
    stream_eng = make_engine(
        ExecutionConfig(stream=StreamConfig(faults=spec, fault_seed=11)),
        quad_loss)
    p2, h2 = stream_eng.execute(plan, params0, batches, eval_fn=_eval)
    trace = sample_trace(spec, n=plan.n_clients, K=plan.n_rounds, seed=11)
    p1, h1 = LocalEngine(quad_loss, ExecutionConfig()) \
        .execute(plan.with_faults(trace), params0, batches, eval_fn=_eval)
    np.testing.assert_array_equal(np.asarray(p1["x"]), np.asarray(p2["x"]))
    _records_equal(h1, h2, check_stream=False)
    assert stream_eng.last_realized_plan.allclose(plan.with_faults(trace))


# ---------------------------------------------------------------------------
# Staleness-weight degeneracy (satellite property tests)
# ---------------------------------------------------------------------------

def test_staleness_weight_values():
    assert staleness_weight(0, "poly", 0.7) == 1.0
    assert staleness_weight(0, "exp", 0.3) == 1.0
    assert staleness_weight(3, "none") == 1.0
    assert staleness_weight(1, "poly", 1.0) == pytest.approx(0.5)
    assert staleness_weight(2, "exp", 0.5) == pytest.approx(0.25)
    with pytest.raises(ValueError):
        staleness_weight(1, "nope")


def test_combine_weights_unit_weight_is_bitwise_noop():
    """weights=1.0 (and an all-ones vector) reduce exactly to the
    active_t mask path -- same floats, bit for bit."""
    rng = np.random.default_rng(0)
    n = 10
    A = jnp.asarray(rng.random((n, n)), jnp.float32)
    tau = jnp.asarray((rng.random(n) < 0.6), jnp.float32)
    act = jnp.asarray((rng.random(n) < 0.8), jnp.float32)
    m = jnp.float32(4.0)
    base = combine_weights(A, tau, m, act)
    for w in (jnp.float32(1.0), jnp.ones(n, jnp.float32)):
        np.testing.assert_array_equal(
            np.asarray(combine_weights(A, tau, m, act, w)),
            np.asarray(base))
    # and a real discount changes only the upload leg scale
    half = combine_weights(A, tau, m, act, jnp.float32(0.5))
    np.testing.assert_allclose(np.asarray(half), 0.5 * np.asarray(base),
                               rtol=1e-6)


def test_stale_path_weight_one_matches_fast_path():
    """Force the buffered (stale) aggregation path with weight 1.0 and
    compare against the synchronous result: same numbers to float
    tolerance (different jit partitioning, same algebra)."""
    plan, params0, batches = _setup(K=4)
    # deadline 0.5 with fixed latency 1.0: every cohort misses its own
    # closure and is consumed one round late at weight 1.0 ('none')
    spec = FaultSpec(latency="fixed", latency_params={"value": 1.0})
    p2, h2 = make_engine(
        ExecutionConfig(backend="aggregate",
                        stream=StreamConfig(deadline=0.5,
                                            faults=spec,
                                            staleness="none")),
        quad_loss).execute(plan, params0, batches)
    assert any(r.stream and r.stream.get("late") for r in h2.records)
    # every record's weighted divisor stays the raw count at weight 1.0
    assert all(not r.stream or "m_weighted" not in r.stream
               for r in h2.records)


# ---------------------------------------------------------------------------
# Replay (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ("einsum", "aggregate"))
def test_fault_trajectory_replays_bitwise_from_json(backend):
    plan, params0, batches = _setup()
    spec = FaultSpec(failures="iid", failure_params={"rate": 0.2},
                     latency="exponential", latency_params={"mean": 0.8},
                     duplicate_rate=0.2, depart_rate=0.02)

    def run(s):
        eng = make_engine(
            ExecutionConfig(backend=backend,
                            stream=StreamConfig(buffer=6, deadline=1.0,
                                                staleness="poly",
                                                staleness_param=0.5,
                                                faults=s, fault_seed=5)),
            quad_loss)
        prm, hist = eng.execute(plan, params0, batches, eval_fn=_eval)
        return prm, hist, eng

    p1, h1, e1 = run(spec)
    p2, h2, e2 = run(FaultSpec.from_json(spec.to_json()))
    np.testing.assert_array_equal(np.asarray(p1["x"]), np.asarray(p2["x"]))
    _records_equal(h1, h2)
    assert e1.last_trace.allclose(e2.last_trace)
    assert e1.last_realized_plan.allclose(e2.last_realized_plan)
    assert e1.last_closures == e2.last_closures


def test_realized_plan_is_a_replayable_artifact():
    """Executing the saved realized plan (faults folded into columns)
    with NO fault spec reproduces the faulty run bitwise -- the
    --plan-out artifact of a stream run pins the whole trajectory."""
    plan, params0, batches = _setup()
    spec = FaultSpec(failures="iid", failure_params={"rate": 0.25},
                     latency="uniform",
                     latency_params={"lo": 0.0, "hi": 1.4})
    stream = StreamConfig(buffer=5, deadline=1.0, staleness="poly")
    eng = make_engine(
        ExecutionConfig(stream=dataclasses.replace(stream, faults=spec)),
        quad_loss)
    p1, h1 = eng.execute(plan, params0, batches)
    realized = RoundPlan.from_json(eng.last_realized_plan.to_json())
    p2, h2 = make_engine(ExecutionConfig(stream=stream), quad_loss) \
        .execute(realized, params0, batches)
    np.testing.assert_array_equal(np.asarray(p1["x"]), np.asarray(p2["x"]))
    # duplicate deliveries live in the trace, not the plan columns, so
    # compare everything except the dup-inflated d2s totals
    for r1, r2 in zip(h1.records, h2.records):
        assert (r1.t, r1.m, r1.m_actual, r1.d2d) == \
            (r2.t, r2.m, r2.m_actual, r2.d2d)


# ---------------------------------------------------------------------------
# Degradation semantics
# ---------------------------------------------------------------------------

def test_deadline_shortfall_recorded_not_fatal():
    plan, params0, batches = _setup()
    spec = FaultSpec(latency="fixed", latency_params={"value": 5.0})
    p2, h2 = make_engine(
        ExecutionConfig(stream=StreamConfig(deadline=1.0, max_staleness=0,
                                            faults=spec)),
        quad_loss).execute(plan, params0, batches)
    # nothing ever arrives in time and everything over-stales away:
    # all rounds degrade gracefully to identity updates
    assert all(r.m_actual == 0 for r in h2.records)
    assert all(r.stream["deadline_hit"] == 1.0 for r in h2.records)
    assert sum(r.stream.get("lost", 0) for r in h2.records) > 0
    np.testing.assert_array_equal(np.asarray(p2["x"]),
                                  np.asarray(params0["x"]))


def test_departures_shrink_participation_permanently():
    plan, params0, batches = _setup(K=8)
    spec = FaultSpec(depart_rate=0.2)
    eng = make_engine(
        ExecutionConfig(stream=StreamConfig(faults=spec, fault_seed=3)),
        quad_loss)
    _, hist = eng.execute(plan, params0, batches)
    gone = int((eng.last_trace.depart_round < 8).sum())
    assert gone > 0
    # the last round's survivors exclude every departed client
    last_active = eng.last_realized_plan.active_t[-1]
    assert (last_active[eng.last_trace.depart_round < 8] == 0).all()


def test_duplicates_billed_as_uplink_but_aggregated_once():
    plan, params0, batches = _setup()
    base = StreamConfig()
    dup = StreamConfig(faults=FaultSpec(duplicate_rate=0.9), fault_seed=2)
    p1, h1 = make_engine(ExecutionConfig(stream=base), quad_loss) \
        .execute(plan, params0, batches)
    p2, h2 = make_engine(ExecutionConfig(stream=dup), quad_loss) \
        .execute(plan, params0, batches)
    # params identical: duplicates are deduplicated before aggregation
    np.testing.assert_array_equal(np.asarray(p1["x"]), np.asarray(p2["x"]))
    # but the uplink ledger bills them
    assert h2.ledger.total_d2s > h1.ledger.total_d2s
    assert sum(r.stream.get("dup", 0) for r in h2.records if r.stream) \
        == h2.ledger.total_d2s - h1.ledger.total_d2s


def test_buffered_closure_accepts_stragglers_late():
    plan, params0, batches = _setup()
    spec = FaultSpec(latency="exponential", latency_params={"mean": 1.2})
    _, hist = make_engine(
        ExecutionConfig(stream=StreamConfig(buffer=4, deadline=2.0,
                                            staleness="poly",
                                            faults=spec, fault_seed=9)),
        quad_loss).execute(plan, params0, batches)
    late = sum(r.stream.get("late", 0) for r in hist.records if r.stream)
    assert late > 0
    weighted = [r.stream["m_weighted"] for r in hist.records
                if r.stream and "m_weighted" in r.stream]
    # staleness discounts pull the weighted divisor under the raw count
    assert weighted and all(
        w < r.m_actual for w, r in zip(
            weighted, (r for r in hist.records
                       if r.stream and "m_weighted" in r.stream)))


# ---------------------------------------------------------------------------
# Engine / config matrix
# ---------------------------------------------------------------------------

def test_resolve_backend_stream_matrix():
    assert resolve_backend(
        ExecutionConfig(backend="pallas", stream=StreamConfig())) \
        == "aggregate"
    assert resolve_backend(
        ExecutionConfig(backend="fused", stream=StreamConfig())) \
        == "aggregate"
    assert resolve_backend(
        ExecutionConfig(backend="einsum", stream=StreamConfig())) \
        == "einsum"
    with pytest.raises(ValueError, match="scan"):
        resolve_backend(ExecutionConfig(scan=True, stream=StreamConfig()))
    with pytest.raises(ValueError, match="record_mixed"):
        resolve_backend(ExecutionConfig(backend="pallas",
                                        record_mixed=True,
                                        stream=StreamConfig()))
    with pytest.raises(ValueError, match="mesh"):
        resolve_backend(ExecutionConfig(stream=StreamConfig(),
                                        mesh=object(), model_cfg=object()))
    with pytest.raises(ValueError):
        resolve_backend(ExecutionConfig(backend="ring",
                                        stream=StreamConfig()))


def test_stream_config_validation():
    with pytest.raises(ValueError):
        StreamConfig(buffer=0)
    with pytest.raises(ValueError):
        StreamConfig(deadline=0.0)
    with pytest.raises(ValueError):
        StreamConfig(staleness="nope")
    with pytest.raises(ValueError):
        StreamConfig(max_staleness=-1)


def test_engine_construction_guards():
    with pytest.raises(ValueError, match="stream"):
        StreamEngine(quad_loss, ExecutionConfig())
    with pytest.raises(ValueError, match="synchronous"):
        LocalEngine(quad_loss, ExecutionConfig(stream=StreamConfig()))
    assert isinstance(
        make_engine(ExecutionConfig(stream=StreamConfig()), quad_loss),
        StreamEngine)


# ---------------------------------------------------------------------------
# Server integration (incl. the split-rng satellite)
# ---------------------------------------------------------------------------

def _server(stream=None, seed=2, t_max=5, execution=None):
    rng = np.random.default_rng(0)
    n, p, T = 12, 3, 3
    targets = rng.standard_normal((n, p)).astype(np.float32)

    def sampler(r, t):
        samp = targets[:, None, None, :] \
            + 0.05 * r.standard_normal((n, T, 2, p))
        return (jnp.asarray(samp, jnp.float32),)

    net = D2DNetwork(n=n, c=2, k_range=(4, 6), p_fail=0.1)
    cfg = ServerConfig(T=T, t_max=t_max, phi_max=0.3, seed=seed,
                       eta=lambda t: 0.2)
    if execution is None:
        execution = ExecutionConfig(stream=stream)
    return FederatedServer(net, quad_loss, {"x": jnp.zeros(p)}, sampler,
                           cfg, algorithm="semidec", execution=execution)


def test_server_runs_stream_engine():
    spec = FaultSpec(failures="iid", failure_params={"rate": 0.2},
                     latency="exponential")
    srv = _server(StreamConfig(buffer=6, deadline=1.5, staleness="poly",
                               faults=spec))
    hist = srv.run(eval_fn=_eval)
    assert len(hist.records) == 5
    assert srv.effective_backend == "einsum"
    assert srv.last_plan is not None


def test_server_built_plans_regenerate():
    """Split rng streams: the server's own plans now embed their seed
    and regenerate() end-to-end (the carried ROADMAP item)."""
    srv = _server(None, execution=ExecutionConfig())
    srv.run()
    plan = srv.last_plan
    assert plan.seed == srv.config.seed
    assert plan.topology is not None
    regen = RoundPlan.from_json(plan.to_json()).regenerate()
    assert regen.allclose(plan)


def test_replay_consumes_identical_batch_stream():
    """Because batches no longer interleave with planning draws,
    replaying the saved plan reproduces the original run bitwise."""
    srv1 = _server(None, execution=ExecutionConfig())
    h1 = srv1.run(eval_fn=_eval)
    srv2 = _server(None, execution=ExecutionConfig())
    h2 = srv2.run(eval_fn=_eval, plan=srv1.last_plan)
    np.testing.assert_array_equal(np.asarray(srv1.params["x"]),
                                  np.asarray(srv2.params["x"]))
    _records_equal(h1, h2)
