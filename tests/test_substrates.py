"""Tests: data pipeline, optimizers, checkpointing, paper CNN."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.ckpt import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.data import (Dataset, FederatedBatcher, dirichlet_partition,
                        iid_partition, label_sorted_partition,
                        make_classification, make_token_stream, lm_batches)
from repro.models.cnn import (accuracy, cnn_apply, init_cnn, init_logreg,
                              init_mlp, l2_regularized_loss, logreg_apply,
                              mlp_apply, softmax_xent)
from repro.optim import adam, clip_by_global_norm, momentum, sgd
from repro.optim.schedules import (cosine, inverse_time, paper_experimental,
                                   warmup_cosine)


# ---------------------------------------------------------------------------
# Data
# ---------------------------------------------------------------------------

def test_classification_dataset_shapes_and_determinism():
    ds1 = make_classification(n_samples=500, seed=3)
    ds2 = make_classification(n_samples=500, seed=3)
    assert ds1.x.shape == (500, 28, 28, 1) and ds1.y.shape == (500,)
    np.testing.assert_array_equal(ds1.x, ds2.x)
    assert set(np.unique(ds1.y)) <= set(range(10))


@given(st.integers(2, 20), st.integers(1, 3))
@settings(max_examples=20, deadline=None)
def test_label_sorted_partition_properties(n_clients, shards):
    ds = make_classification(n_samples=1200, seed=0)
    parts = label_sorted_partition(ds, n_clients, shards_per_client=shards)
    assert len(parts) == n_clients
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(np.unique(all_idx))   # disjoint
    # shards are contiguous intervals of the label-sorted order, so the
    # total number of (shard, label) incidences is at most
    # n_shards + n_labels - 1; per client that sums over its shards.
    n_shards = n_clients * shards
    total_incidences = sum(len(np.unique(ds.y[p])) for p in parts)
    assert total_incidences <= n_shards + 10 - 1


def test_label_sorted_partition_extreme_heterogeneity():
    """Paper: 70 clients, 2 chunks each => ~2 labels per client."""
    ds = make_classification(n_samples=7000, seed=1)
    parts = label_sorted_partition(ds, 70, 2)
    label_counts = [len(np.unique(ds.y[p])) for p in parts]
    assert np.mean(label_counts) <= 3.0


def test_dirichlet_and_iid_partitions_cover():
    ds = make_classification(n_samples=1000, seed=2)
    for parts in (dirichlet_partition(ds, 10, 0.5), iid_partition(ds, 10)):
        total = sum(len(p) for p in parts)
        assert total >= 0.9 * len(ds)
        all_idx = np.concatenate(parts)
        assert len(all_idx) == len(np.unique(all_idx))


def test_federated_batcher_shapes():
    ds = make_classification(n_samples=600, seed=0)
    parts = label_sorted_partition(ds, 6, 2)
    batcher = FederatedBatcher(ds, parts, T=4, batch_size=8)
    x, y = batcher(np.random.default_rng(0), 0)
    assert x.shape == (6, 4, 8, 28, 28, 1)
    assert y.shape == (6, 4, 8)


def test_token_stream_and_lm_batches():
    toks = make_token_stream(n_tokens=4096, vocab=97, seed=0)
    assert toks.min() >= 0 and toks.max() < 97
    x, y = lm_batches(toks, np.random.default_rng(0), n_clients=4, T=2,
                      batch_size=3, seq_len=16)
    assert x.shape == (4, 2, 3, 16) and y.shape == x.shape
    # causal shift property
    x0 = np.asarray(x[0, 0, 0])
    y0 = np.asarray(y[0, 0, 0])
    np.testing.assert_array_equal(x0[1:], y0[:-1])


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def _rosenbrock_grad_steps(opt, steps=400, lr=2e-3, jit_step=True):
    params = {"x": jnp.array([-1.0, 1.5])}

    def loss(p):
        x, y = p["x"][0], p["x"][1]
        return (1 - x) ** 2 + 100 * (y - x ** 2) ** 2

    state = opt.init(params)

    @jax.jit
    def one(params, state):
        g = jax.grad(loss)(params)
        return opt.update(g, state, params, jnp.float32(lr))

    for _ in range(steps):
        params, state = one(params, state)
    return float(loss(params))


def test_sgd_momentum_adam_descend():
    assert _rosenbrock_grad_steps(sgd()) < 4.0
    assert _rosenbrock_grad_steps(momentum(0.9)) < 1.0
    assert _rosenbrock_grad_steps(adam(), steps=2000, lr=2e-2) < 0.1


def test_adam_bias_correction_first_step():
    opt = adam(b1=0.9, b2=0.999)
    params = {"w": jnp.array([1.0])}
    state = opt.init(params)
    g = {"w": jnp.array([0.5])}
    new, _ = opt.update(g, state, params, jnp.float32(0.1))
    # first Adam step is ~ -lr * sign-ish: m_hat/sqrt(v_hat) = 1
    np.testing.assert_allclose(np.asarray(new["w"]), [0.9], atol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0, 4.0])}           # norm 5
    clipped = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               rtol=1e-5)
    unclipped = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(unclipped["a"]), [3.0, 4.0],
                               rtol=1e-5)


def test_schedules():
    assert paper_experimental()(0) == pytest.approx(0.02)
    assert paper_experimental()(1) == pytest.approx(0.002)
    s = inverse_time(4.0, 10.0)
    assert s(0) == pytest.approx(0.4) and s(10) == pytest.approx(0.2)
    c = cosine(1.0, 100)
    assert c(0) == pytest.approx(1.0) and c(100) == pytest.approx(0.0, abs=1e-9)
    w = warmup_cosine(1.0, 10, 110)
    assert w(0) == pytest.approx(0.1) and w(9) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    params = {"layer": {"w": jnp.arange(6.0).reshape(2, 3),
                        "b": jnp.zeros(3)},
              "head": jnp.ones((4,), jnp.float32)}
    p = save_checkpoint(str(tmp_path), 7, params, meta={"m_next": 12})
    assert latest_checkpoint(str(tmp_path)) == p
    restored, meta = load_checkpoint(p, jax.tree.map(jnp.zeros_like, params))
    assert meta["step"] == 7 and meta["meta"]["m_next"] == 12
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_mismatch(tmp_path):
    params = {"w": jnp.ones(3)}
    for s in range(5):
        save_checkpoint(str(tmp_path), s, params, keep=2)
    ckpts = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(ckpts) == 2
    bad = {"w": jnp.ones(3), "extra": jnp.ones(1)}
    with pytest.raises(ValueError):
        load_checkpoint(latest_checkpoint(str(tmp_path)), bad)
    with pytest.raises(ValueError):
        load_checkpoint(latest_checkpoint(str(tmp_path)),
                        {"w": jnp.ones((4,))})


# ---------------------------------------------------------------------------
# Paper CNN / MLP / logreg
# ---------------------------------------------------------------------------

def test_cnn_shapes_and_param_count():
    params = init_cnn(seed=0)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    # paper reports ~1.66M for this architecture
    assert abs(n_params - 1_663_370) < 10_000
    x = jnp.zeros((2, 28, 28, 1))
    logits = cnn_apply(params, x)
    assert logits.shape == (2, 10)
    assert not bool(jnp.isnan(logits).any())


def test_models_learn_synthetic_task():
    ds = make_classification(n_samples=1024, seed=0)
    x, y = jnp.asarray(ds.x), jnp.asarray(ds.y)

    for init, apply, lr in ((init_mlp, mlp_apply, 0.1),
                            (init_logreg, logreg_apply, 0.1)):
        params = init(seed=0)

        @jax.jit
        def step(p, xb, yb):
            g = jax.grad(lambda q: softmax_xent(apply(q, xb), yb))(p)
            return jax.tree.map(lambda a, b: a - lr * b, p, g)

        for i in range(60):
            sl = slice((i * 64) % 1024, (i * 64) % 1024 + 64)
            params = step(params, x[sl], y[sl])
        acc = accuracy(apply, params, x, y)
        assert acc > 0.6, f"{apply.__name__} failed to learn: acc={acc}"


def test_l2_regularized_loss_strongly_convex_grad():
    """grad difference inner product >= mu ||x-y||^2 spot check."""
    params_a = init_logreg(seed=0)
    params_b = init_logreg(seed=1)
    ds = make_classification(n_samples=64, seed=0)
    batch = (jnp.asarray(ds.x), jnp.asarray(ds.y))
    mu = 0.05
    loss = lambda p: l2_regularized_loss(logreg_apply, p, batch, mu=mu)
    ga = jax.grad(loss)(params_a)
    gb = jax.grad(loss)(params_b)
    inner = sum(jnp.sum((x - y) * (u - v)) for x, y, u, v in zip(
        jax.tree.leaves(ga), jax.tree.leaves(gb),
        jax.tree.leaves(params_a), jax.tree.leaves(params_b)))
    sq = sum(jnp.sum((u - v) ** 2) for u, v in zip(
        jax.tree.leaves(params_a), jax.tree.leaves(params_b)))
    assert float(inner) >= mu * float(sq) - 1e-6
