"""Theory layer across topology families (ISSUE 5 satellite).

For EVERY registered ``repro.topology`` family: the server's degree-only
bound ``phi_ell_bound_from_stats`` dominates the oracle ``exact_phi_ell``
(with the documented O(eps^2) slack of Prop. 5.1's truncation), and the
``min_clients`` threshold rule stays monotone in ``phi_max``.
Hypothesis-driven where available (tests/hypothesis_compat.py) with a
seeded parametrized fallback that always runs -- the same pattern as
tests/test_core_bounds.py, now spanning connectivity regimes from the
paper's k-regular clusters to the ring/hub extremes.
"""

import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro import topology
from repro.core.bounds import exact_phi_ell, phi_ell_bound_from_stats
from repro.core.graphs import degree_stats
from repro.core.sampling import min_clients

ALL_FAMILIES = topology.families()


def _family_graphs(family, seed, n=24, c=3, rounds=3):
    """A short trajectory of cluster adjacencies from one family."""
    model = topology.make_spec(family, n=n, c=c).build()
    rng = np.random.default_rng(seed)
    ws = []
    for t in range(rounds):
        ws.extend(cg.W for cg in model.sample(rng, t))
    return ws


def _check_bound_dominates(family, seed):
    for W in _family_graphs(family, seed):
        stats = degree_stats(W)
        bound = phi_ell_bound_from_stats(stats, "auto")
        exact = exact_phi_ell(W)
        # Prop. 5.1 truncates at O(eps^2); same documented slack as the
        # test_core_bounds.py domination suite
        slack = 4.0 * stats.eps ** 2 + 1e-6
        assert bound + slack >= exact, (family, stats, bound, exact)


def _check_min_clients_monotone(family, seed, n=24, c=3):
    model = topology.make_spec(family, n=n, c=c).build()
    rng = np.random.default_rng(seed)
    clusters = model.sample(rng, 0)
    psis = [phi_ell_bound_from_stats(c.stats, "auto") for c in clusters]
    sizes = [c.size for c in clusters]
    grid = [0.0, 0.01, 0.05, 0.2, 0.5, 1.0, 4.0, 1e6]
    ms = [min_clients(psis, sizes, n, phi) for phi in grid]
    assert all(1 <= m <= n for m in ms)
    # looser threshold can only shrink the sample: non-increasing in
    # phi_max, pinned at the extremes
    assert all(a >= b for a, b in zip(ms, ms[1:])), (family, ms)
    assert ms[0] == n
    if sum(psis) > 0:
        assert ms[-1] == 1


# ---------------------------------------------------------------------------
# hypothesis-driven (skip-degrades without the dev extra)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ALL_FAMILIES)
@given(seed=st.integers(0, 2**31))
@settings(max_examples=15, deadline=None)
def test_bound_dominates_exact_phi_property(family, seed):
    _check_bound_dominates(family, seed)


@pytest.mark.parametrize("family", ALL_FAMILIES)
@given(seed=st.integers(0, 2**31))
@settings(max_examples=15, deadline=None)
def test_min_clients_monotone_property(family, seed):
    _check_min_clients_monotone(family, seed)


# ---------------------------------------------------------------------------
# seeded fallback (always runs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ALL_FAMILIES)
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_bound_dominates_exact_phi_seeded(family, seed):
    _check_bound_dominates(family, seed)


@pytest.mark.parametrize("family", ALL_FAMILIES)
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_min_clients_monotone_seeded(family, seed):
    _check_min_clients_monotone(family, seed)


# ---------------------------------------------------------------------------
# regime sanity: the families actually span the degree-stat space the
# bound machinery is supposed to be exercised over
# ---------------------------------------------------------------------------

def test_families_span_distinct_degree_regimes():
    stats = {}
    for family in ALL_FAMILIES:
        W = _family_graphs(family, seed=0, rounds=1)[0]
        stats[family] = degree_stats(W)
    # the paper's family: near-regular, alpha comfortably > 1/2
    assert stats["k_regular"].alpha > 0.5
    # ring: sparse worst case -- tiny alpha, zero degree spread
    assert stats["ring"].alpha <= 0.5
    assert stats["ring"].eps == 0.0 and stats["ring"].varphi == 0.0
    # hub: the D2S-degenerate extreme -- in-degree explodes at the hub
    assert stats["hub"].varphi > 1.0
    assert stats["hub"].d_max_in == stats["hub"].size
    # and the m(t) consequences differ: the sparse ring forces more
    # uplinks than a clean k-regular cluster (eps = 0: Prop. 5.1 regime)
    n, c = 24, 3
    m_at = {}
    for family, kw in (("k_regular", {"p_fail": 0.0}), ("ring", {})):
        model = topology.make_spec(family, n=n, c=c, **kw).build()
        clusters = model.sample(np.random.default_rng(0), 0)
        psis = [phi_ell_bound_from_stats(cg.stats, "auto")
                for cg in clusters]
        m_at[family] = min_clients(psis, [cg.size for cg in clusters],
                                   n, 0.2)
    assert m_at["ring"] > m_at["k_regular"]


def test_preferential_attachment_heavy_tail():
    """PA grows a scale-free in-degree tail: early nodes accumulate far
    more in-links than anyone sends (d_max_in >> d_max_out), the regime
    where degree-stat bounds go loose and adaptive control pays off."""
    model = topology.make_spec("preferential_attachment", n=60,
                               c=1).build()
    cg = model.sample(np.random.default_rng(0), 0)[0]
    stats = degree_stats(cg.W)
    assert stats.d_max_in >= 5 * stats.d_max_out, stats
    assert stats.varphi > 1.0, stats
    # the tail is a property of the growth process, not one seed
    for seed in (1, 2):
        cg = model.sample(np.random.default_rng(seed), 0)[0]
        s = degree_stats(cg.W)
        assert s.d_max_in > s.d_max_out, s
