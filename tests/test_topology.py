"""The declarative topology layer (ISSUE 5 tentpole).

Covers: the registry + spec JSON round-trips for every family, the
``k_regular`` family's bitwise reproduction of the legacy
``D2DNetwork.sample`` rng stream (pinned against an inline copy of the
pre-redesign loop), membership schemes (equal / skewed / explicit /
periodic re-clustering), time-correlated sampling (geometric mobility),
the CLI spec parser, and -- the acceptance criterion -- that
``connectivity_aware`` plans build, embed their spec, regenerate
bitwise from it, and execute on the ``LocalEngine`` for every
registered family with finite ``psi_bound`` columns.
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro import topology
from repro.core.adjacency import is_column_stochastic, network_matrix
from repro.core.graphs import (ClusterGraph, D2DNetwork,
                               delete_edge_fraction, k_regular_digraph)
from repro.core.server import FederatedServer, ServerConfig
from repro.fl import ExecutionConfig, RoundPlan, make_engine

ALL_FAMILIES = topology.families()


def quad_loss(params, batch):
    x = params["x"]
    b, = batch
    return 0.5 * jnp.sum((x - b.mean(axis=0)) ** 2)


def _quad_batches(n, rounds, p=3, T=2, B=2, seed=0):
    rng = np.random.default_rng(seed)
    return [(jnp.asarray(rng.standard_normal((n, T, B, p)), jnp.float32),)
            for _ in range(rounds)]


# ---------------------------------------------------------------------------
# the bitwise pin: k_regular == the pre-redesign D2DNetwork.sample loop
# ---------------------------------------------------------------------------

def _legacy_sample(n, c, k_range, p_fail, self_loops, rng,
                   partition=None):
    """Verbatim copy of the pre-redesign ``D2DNetwork.sample`` loop --
    the reference this PR's shim and ``topology.k_regular`` must
    reproduce bitwise."""
    if partition is None:
        per = n // c
        partition = [np.arange(l * per, (l + 1) * per) for l in range(c)]
    out = []
    for verts in partition:
        s = len(verts)
        k = int(rng.integers(min(k_range), max(k_range) + 1))
        k = min(k, s)
        W = k_regular_digraph(s, k, rng, self_loops=self_loops)
        if p_fail > 0:
            W = delete_edge_fraction(W, p_fail, rng)
        out.append(ClusterGraph(vertices=np.asarray(verts), W=W))
    return out


@pytest.mark.parametrize("seed", [0, 7, 1234])
@pytest.mark.parametrize("n,c,k_range,p_fail", [
    (70, 7, (6, 9), 0.1),
    (12, 2, (4, 6), 0.0),
    (24, 3, (3, 3), 0.3),
])
def test_k_regular_matches_legacy_stream_bitwise(n, c, k_range, p_fail,
                                                 seed):
    r_legacy, r_shim, r_model = (np.random.default_rng(seed)
                                 for _ in range(3))
    want = [_legacy_sample(n, c, k_range, p_fail, True, r_legacy)
            for _ in range(3)]
    shim = D2DNetwork(n=n, c=c, k_range=k_range, p_fail=p_fail)
    model = topology.make_spec("k_regular", n=n, c=c, k_range=k_range,
                               p_fail=p_fail).build()
    for t, ref in enumerate(want):
        got_shim = shim.sample(r_shim, t)
        got_model = model.sample(r_model, t)
        for a, b, d in zip(ref, got_shim, got_model):
            np.testing.assert_array_equal(a.W, b.W)
            np.testing.assert_array_equal(a.W, d.W)
            np.testing.assert_array_equal(a.vertices, b.vertices)
            np.testing.assert_array_equal(a.vertices, d.vertices)


def test_k_regular_explicit_partition_matches_legacy():
    parts = [np.array([0, 3, 5, 7, 9, 11]), np.array([1, 2, 4, 6, 8, 10])]
    r1, r2 = np.random.default_rng(5), np.random.default_rng(5)
    want = _legacy_sample(12, 2, (3, 4), 0.2, True, r1,
                          partition=[p.copy() for p in parts])
    shim = D2DNetwork(n=12, c=2, k_range=(3, 4), p_fail=0.2,
                      partition=[p.copy() for p in parts])
    got = shim.sample(r2)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a.W, b.W)
        np.testing.assert_array_equal(a.vertices, b.vertices)
    # and the spec round-trips the explicit membership
    spec = shim.spec
    assert spec.membership == "explicit"
    rebuilt = topology.build(spec)
    r3 = np.random.default_rng(5)
    for a, b in zip(want, rebuilt.sample(r3, 0)):
        np.testing.assert_array_equal(a.W, b.W)


# ---------------------------------------------------------------------------
# registry + spec serialization
# ---------------------------------------------------------------------------

def test_registry_has_the_required_families():
    assert {"k_regular", "erdos_renyi", "geometric", "ring",
            "small_world", "hub"} <= set(ALL_FAMILIES)
    assert len(ALL_FAMILIES) >= 5


@pytest.mark.parametrize("family", ALL_FAMILIES)
def test_spec_json_round_trip_exact(family):
    spec = topology.make_spec(family, n=24, c=3)
    back = topology.TopologySpec.from_dict(json.loads(spec.to_json()))
    assert back == spec
    assert back.to_json() == spec.to_json()
    # from_json builds a working model of the same spec
    model = topology.from_json(spec.to_json())
    assert model.spec == spec


def test_spec_round_trip_with_nondefault_and_tuple_params():
    spec = topology.make_spec(
        "k_regular", n=20, c=2, k_range=(3, 5), p_fail=0.25,
        self_loops=False, membership="skewed",
        membership_params={"gamma": 0.5, "recluster_every": 3})
    back = topology.TopologySpec.from_dict(json.loads(spec.to_json()))
    assert back == spec
    assert back.params["k_range"] == (3, 5)       # tuple survives JSON


def test_make_spec_validates_names_and_params():
    with pytest.raises(ValueError, match="unknown topology family"):
        topology.make_spec("nope", n=10, c=2)
    with pytest.raises(ValueError, match="unknown parameter"):
        topology.make_spec("ring", n=10, c=2, radius=0.3)
    with pytest.raises(ValueError, match="membership"):
        topology.make_spec("ring", n=10, c=2, membership="wat")
    with pytest.raises(ValueError, match="membership parameter"):
        topology.make_spec("ring", n=10, c=2,
                           membership_params={"gamma": 0.5})


def test_parse_spec_cli_syntax():
    spec = topology.parse_spec("k_regular:k_range=6-9,p_fail=0.2", n=70,
                               c=7)
    assert spec.params["k_range"] == (6, 9)
    assert spec.params["p_fail"] == 0.2
    spec = topology.parse_spec(
        "geometric:radius=0.3,membership=skewed,gamma=0.6,"
        "recluster_every=4,self_loops=false", n=20, c=2)
    assert spec.family == "geometric" and spec.membership == "skewed"
    assert spec.membership_params == {"gamma": 0.6, "recluster_every": 4}
    assert spec.params["self_loops"] is False
    assert topology.parse_spec("ring", n=10, c=2).family == "ring"
    with pytest.raises(ValueError, match="key=val"):
        topology.parse_spec("ring:hops", n=10, c=2)


# ---------------------------------------------------------------------------
# families produce valid cluster digraphs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ALL_FAMILIES)
@pytest.mark.parametrize("seed", [0, 3])
def test_family_snapshots_are_valid(family, seed):
    model = topology.make_spec(family, n=24, c=3).build()
    rng = np.random.default_rng(seed)
    for t in range(3):
        clusters = model.sample(rng, t)
        assert len(clusters) == 3
        all_verts = np.concatenate([c.vertices for c in clusters])
        assert sorted(all_verts.tolist()) == list(range(24))
        for cg in clusters:
            assert (cg.W.sum(axis=1) >= 1).all()     # positive out-degree
            assert cg.stats.size == cg.size          # degree_stats works
        A = network_matrix(clusters, 24)
        assert is_column_stochastic(A)


def test_membership_equal_matches_legacy_partition():
    parts = topology.make_partition(70, 7, "equal")
    for l, v in enumerate(parts):
        np.testing.assert_array_equal(v, np.arange(10 * l, 10 * (l + 1)))
    with pytest.raises(ValueError, match="c | n"):
        topology.make_partition(10, 3, "equal")


def test_membership_skewed_covers_and_skews():
    parts = topology.make_partition(30, 3, "skewed", {"gamma": 0.5})
    sizes = [len(v) for v in parts]
    assert sum(sizes) == 30 and min(sizes) >= 1
    assert sizes == sorted(sizes, reverse=True) and sizes[0] > sizes[-1]
    assert sorted(np.concatenate(parts).tolist()) == list(range(30))


def test_membership_periodic_reclustering():
    model = topology.make_spec(
        "erdos_renyi", n=12, c=2,
        membership_params={"recluster_every": 2}).build()
    rng = np.random.default_rng(0)
    parts = []
    for t in range(4):
        parts.append([c.vertices.tolist()
                      for c in model.sample(rng, t)])
    assert parts[0] == parts[1]          # shuffle only at the period
    assert parts[2] != parts[0]          # t=2: re-clustered
    assert parts[2] == parts[3]
    for p in parts:                      # sizes + coverage preserved
        assert [len(v) for v in p] == [6, 6]
        assert sorted(sum(p, [])) == list(range(12))


def test_time_correlated_requires_consecutive_t():
    model = topology.make_spec("geometric", n=12, c=2).build()
    rng = np.random.default_rng(0)
    model.sample(rng, 0)
    model.sample(rng, 1)
    with pytest.raises(ValueError, match="consecutive"):
        model.sample(rng, 5)
    model.sample(rng, 0)                 # t=0 resets the trajectory
    model.sample(rng, 1)


def test_geometric_snapshots_are_time_correlated_and_deterministic():
    spec = topology.make_spec("geometric", n=20, c=2, radius=0.4,
                              speed=0.05)
    model = spec.build()
    rng = np.random.default_rng(0)
    snaps = [model.sample(rng, t) for t in range(3)]

    def edges(clusters):
        return set((l,) + tuple(e) for l, c in enumerate(clusters)
                   for e in np.argwhere(c.W))

    e0, e1 = edges(snaps[0]), edges(snaps[1])
    overlap = len(e0 & e1) / len(e0 | e1)
    # small per-round motion => consecutive snapshots share most edges
    assert overlap > 0.5
    # an independent draw (different seed) shares far fewer
    fresh = edges(spec.build().sample(np.random.default_rng(99), 0))
    assert len(e0 & fresh) / len(e0 | fresh) < overlap
    # same seed => bitwise-identical trajectory (the regenerate contract)
    model2 = spec.build()
    rng2 = np.random.default_rng(0)
    for t, ref in enumerate(snaps):
        got = model2.sample(rng2, t)
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a.W, b.W)


# ---------------------------------------------------------------------------
# acceptance: plans build, embed provenance, regenerate, and execute on
# the LocalEngine for every registered family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ALL_FAMILIES)
def test_connectivity_aware_plan_builds_and_executes(family):
    spec = topology.make_spec(family, n=12, c=2)
    model = spec.build()
    cfg = ServerConfig(T=2, t_max=3, phi_max=0.3, seed=0)
    plan = RoundPlan.connectivity_aware(model, cfg)
    assert np.isfinite(plan.psi_bound_t).all()
    assert plan.topology == spec and plan.seed == 0
    np.testing.assert_allclose(plan.A_t.sum(axis=1), 1.0, atol=1e-5)

    engine = make_engine(ExecutionConfig(backend="einsum"), quad_loss)
    params, hist = engine.execute(plan, {"x": jnp.zeros(3)},
                                  _quad_batches(12, 3))
    assert len(hist.records) == 3
    assert np.isfinite(np.asarray(params["x"])).all()


@pytest.mark.parametrize("family", ALL_FAMILIES)
def test_plan_regenerates_bitwise_from_embedded_spec(family):
    model = topology.make_spec(family, n=12, c=2).build()
    cfg = ServerConfig(T=2, t_max=4, phi_max=0.3, seed=11)
    plan = RoundPlan.connectivity_aware(model, cfg)
    back = RoundPlan.from_json(plan.to_json())
    assert back.topology == plan.topology and back.seed == plan.seed
    regen = back.regenerate()
    assert regen.allclose(plan)
    # dropout plans regenerate through the same provenance
    dropped = plan.with_dropout(0.3, np.random.default_rng(2))
    regen_d = RoundPlan.from_json(dropped.to_json()).regenerate()
    assert regen_d.allclose(dropped)


def test_legacy_d2dnetwork_plan_regenerates_from_embedded_spec():
    """The pinned pre-redesign path: a plan built from the deprecated
    ``D2DNetwork`` shim serializes with an embedded k_regular spec and
    regenerates its columns bitwise."""
    net = D2DNetwork(n=12, c=2, k_range=(4, 6), p_fail=0.1)
    cfg = ServerConfig(T=3, t_max=5, phi_max=0.3, seed=3)
    for ctor in (RoundPlan.connectivity_aware, RoundPlan.colrel,
                 RoundPlan.fedavg):
        kw = (ServerConfig(T=3, t_max=5, phi_max=0.3, seed=3, m_fixed=6)
              if ctor is not RoundPlan.connectivity_aware else cfg)
        plan = ctor(net, kw)
        assert plan.topology is not None
        assert plan.topology.family == "k_regular"
        regen = RoundPlan.from_json(plan.to_json()).regenerate()
        assert regen.allclose(plan)


def test_plan_without_provenance_refuses_to_regenerate():
    net = D2DNetwork(n=12, c=2, k_range=(4, 6))
    cfg = ServerConfig(T=2, t_max=2, phi_max=0.3, seed=0)
    # external rng: replayable, not regenerable
    plan = RoundPlan.connectivity_aware(net, cfg,
                                        rng=np.random.default_rng(0))
    assert plan.seed is None
    with pytest.raises(ValueError, match="provenance"):
        plan.regenerate()


def test_version1_plan_json_still_loads():
    net = D2DNetwork(n=12, c=2, k_range=(4, 6))
    plan = RoundPlan.connectivity_aware(
        net, ServerConfig(T=2, t_max=2, phi_max=0.3, seed=0))
    d = json.loads(plan.to_json())
    for legacy_absent in ("topology", "seed", "t0"):
        d.pop(legacy_absent)
    d["version"] = 1
    old = RoundPlan.from_json(json.dumps(d))
    assert old.allclose(plan)
    assert old.topology is None and old.seed is None and old.t0 == 0


def test_server_runs_any_topology_model():
    """FederatedServer accepts a TopologyModel directly (not just the
    deprecated shim) -- and a time-correlated family works end-to-end."""
    model = topology.make_spec("geometric", n=12, c=2, radius=0.45).build()
    cfg = ServerConfig(T=2, t_max=3, phi_max=0.3, seed=0)
    rng = np.random.default_rng(1)
    targets = rng.standard_normal((12, 3)).astype(np.float32)

    def sampler(r, t):
        samp = targets[:, None, None, :] \
            + 0.05 * r.standard_normal((12, 2, 2, 3))
        return (jnp.asarray(samp, jnp.float32),)

    server = FederatedServer(model, quad_loss, {"x": jnp.zeros(3)},
                             sampler, cfg, algorithm="semidec",
                             execution=ExecutionConfig(backend="einsum"))
    hist = server.run()
    assert len(hist.records) == 3
    assert server.last_plan.topology == model.spec
